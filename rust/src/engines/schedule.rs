//! Tick schedules for both engines — pure index arithmetic, heavily
//! property-tested, shared by the real engines and the paper-scale replay.
//!
//! Everything reduces to one question: *which inner virtual index `vk`
//! does process `(i, j)` (replica `l`) consume at tick `t`, for which C
//! panel?*
//!
//! **Cannon (Algorithm 1).**  After the pre-shift (A row-shifted by `i`,
//! B column-shifted by `j`), the unique virtual index present at `(i, j)`
//! on tick `t` that satisfies both residue conditions
//! `vk ≡ i + j + t (mod P_C)` (A's ring) and `vk ≡ i + j + t (mod P_R)`
//! (B's ring) is `vk = (i + j + t) mod V` with `V = lcm(P_R, P_C)` — the
//! reason the virtual dimension is the lcm.
//!
//! **2.5D one-sided (Algorithm 2).**  Process `(i, j)` has reduced
//! coordinates `i0 = i mod side3D`, `j0 = j mod side3D` and replica index
//! `l = j3D·L_R + i3D`.  It contributes to the `L = L_R·L_C` C panels
//! `(m_a, n_b)`, `m_a = a·side3D + i0`, `n_b = b·side3D + j0`.  At tick
//! `T ∈ [0, V/L)` all `L` of its products use the *same* inner index
//!
//! ```text
//!     vk(l, T) = (i0 + j0 + l·(V/L) + T) mod V
//! ```
//!
//! which (a) tiles `[0, V)` exactly once across the `L` replicas of every
//! C panel (the `l·(V/L) + T` term is a bijection onto `[0, V)`), and
//! (b) is shared by all `L` products of the tick, so the `L_R` A panels
//! and `L_C` B panels fetched once per tick are each reused — the √L
//! communication reduction of paper Eq. 7 with the buffer counts of
//! Algorithm 2 (`max(2, L_R)` A buffers, 2 B buffers).

use crate::dist::topology25d::Topology25d;

/// Cannon inner index at tick `t` for process `(i, j)`.
#[inline]
pub fn cannon_vk(topo: &Topology25d, i: usize, j: usize, t: usize) -> usize {
    (i + j + t) % topo.v
}

/// 2.5D inner index at tick `big_t` for process `(i, j)` (same for all of
/// the tick's L products).
#[inline]
pub fn osl_vk(topo: &Topology25d, i: usize, j: usize, big_t: usize) -> usize {
    let i0 = i % topo.side3d;
    let j0 = j % topo.side3d;
    let (_, _, l) = topo.coords3d(i, j);
    (i0 + j0 + l * (topo.v / topo.l) + big_t) % topo.v
}

/// The products of one 2.5D tick: `(panel_a_idx, panel_b_idx, m, n)` in
/// Algorithm 2's sub-step order (`icomm3D = s mod L_R` fastest, so each B
/// panel is consumed over `L_R` consecutive products — why 2 B buffers
/// suffice).
pub fn osl_tick_products(
    topo: &Topology25d,
    i: usize,
    j: usize,
) -> Vec<(usize, usize, usize, usize)> {
    let i0 = i % topo.side3d;
    let j0 = j % topo.side3d;
    let mut out = Vec::with_capacity(topo.l);
    for b in 0..topo.l_c {
        for a in 0..topo.l_r {
            out.push((a, b, a * topo.side3d + i0, b * topo.side3d + j0));
        }
    }
    out
}

/// Full coverage enumeration for one C panel `(m, n)`: the `(vk, replica)`
/// pairs contributed over the whole multiplication.  Test helper and the
/// basis of the replay's volume accounting.
pub fn osl_panel_coverage(topo: &Topology25d, m: usize, n: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(topo.v);
    for (i, j) in topo.replicas_of_panel(m, n) {
        let (_, _, l) = topo.coords3d(i, j);
        for big_t in 0..topo.nticks() {
            out.push((osl_vk(topo, i, j, big_t), l));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::dist::grid::ProcGrid;
    use crate::util::testkit::property;

    fn topo(pr: usize, pc: usize, l: usize) -> Topology25d {
        Topology25d::new(ProcGrid::new(pr, pc).unwrap(), l).unwrap()
    }

    #[test]
    fn cannon_covers_all_vk() {
        for (pr, pc) in [(2, 2), (3, 3), (2, 3), (10, 20), (4, 6)] {
            let t = topo(pr, pc, 1);
            for i in 0..pr {
                for j in 0..pc {
                    let mut seen: Vec<usize> =
                        (0..t.v).map(|tick| cannon_vk(&t, i, j, tick)).collect();
                    seen.sort_unstable();
                    assert_eq!(seen, (0..t.v).collect::<Vec<_>>());
                }
            }
        }
    }

    #[test]
    fn cannon_vk_satisfies_both_residues() {
        // The panel consumed at tick t must reside at (i,j) under both the
        // A ring (mod P_C) and B ring (mod P_R) after the pre-shift.
        property("cannon residues", 17, 60, |rng, _| {
            let pr = 1 + rng.usize_below(6);
            let pc = 1 + rng.usize_below(6);
            let t = topo(pr, pc, 1);
            let i = rng.usize_below(pr);
            let j = rng.usize_below(pc);
            let tick = rng.usize_below(t.v);
            let vk = cannon_vk(&t, i, j, tick);
            if vk % pc != (i + j + tick) % pc {
                return Err(format!("A residue broken: {pr}x{pc} ({i},{j}) t={tick}"));
            }
            if vk % pr != (i + j + tick) % pr {
                return Err(format!("B residue broken: {pr}x{pc} ({i},{j}) t={tick}"));
            }
            Ok(())
        });
    }

    #[test]
    fn osl_reduces_to_cannon_at_l1() {
        for (pr, pc) in [(3, 3), (2, 4), (4, 4)] {
            let t = topo(pr, pc, 1);
            for i in 0..pr {
                for j in 0..pc {
                    for tick in 0..t.v {
                        assert_eq!(osl_vk(&t, i, j, tick), cannon_vk(&t, i, j, tick));
                    }
                }
            }
        }
    }

    #[test]
    fn osl_panel_coverage_is_exact_partition() {
        // THE core 2.5D invariant: over all replicas and ticks, C panel
        // (m, n) receives each inner index vk exactly once.
        for (pr, pc, l) in [
            (4, 4, 4),
            (20, 20, 4),
            (27, 27, 9),
            (9, 9, 9),
            (10, 20, 2),
            (20, 10, 2),
            (4, 8, 2),
            (12, 4, 3),
            (4, 4, 1),
            (36, 36, 9),
        ] {
            let t = topo(pr, pc, l);
            for m in (0..pr).step_by((pr / 3).max(1)) {
                for n in (0..pc).step_by((pc / 3).max(1)) {
                    let mut vks: Vec<usize> = osl_panel_coverage(&t, m, n)
                        .into_iter()
                        .map(|(vk, _)| vk)
                        .collect();
                    vks.sort_unstable();
                    assert_eq!(
                        vks,
                        (0..t.v).collect::<Vec<_>>(),
                        "coverage broken for {pr}x{pc} L={l} panel ({m},{n})"
                    );
                }
            }
        }
    }

    #[test]
    fn osl_vk_shared_within_tick() {
        // All L products of a tick share one vk — the reuse that buys the
        // sqrt(L) communication reduction.
        let t = topo(8, 8, 4);
        for i in 0..8 {
            for j in 0..8 {
                for big_t in 0..t.nticks() {
                    let vk = osl_vk(&t, i, j, big_t);
                    // no per-product variation by construction; assert the
                    // products enumerate the right panels instead
                    let prods = osl_tick_products(&t, i, j);
                    assert_eq!(prods.len(), 4);
                    for (a, b, m, n) in prods {
                        assert_eq!(m % t.side3d, i % t.side3d);
                        assert_eq!(n % t.side3d, j % t.side3d);
                        assert_eq!(m / t.side3d, a);
                        assert_eq!(n / t.side3d, b);
                    }
                    let _ = vk;
                }
            }
        }
    }

    #[test]
    fn osl_tick_products_order_buffers() {
        // A-panel index (a) varies fastest: B panel b is consumed over L_R
        // consecutive products, then never again — double buffering is
        // sufficient for B, as the paper states.
        let t = topo(9, 9, 9);
        let prods = osl_tick_products(&t, 1, 2);
        assert_eq!(prods.len(), 9);
        let b_seq: Vec<usize> = prods.iter().map(|&(_, b, _, _)| b).collect();
        assert_eq!(b_seq, vec![0, 0, 0, 1, 1, 1, 2, 2, 2]);
        let a_seq: Vec<usize> = prods.iter().map(|&(a, _, _, _)| a).collect();
        assert_eq!(a_seq, vec![0, 1, 2, 0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn nonsquare_orientations_cover() {
        // tall grid: replication along rows (L_R = L)
        let t = topo(8, 4, 2);
        assert_eq!((t.l_r, t.l_c), (2, 1));
        for m in 0..8 {
            let mut vks: Vec<usize> = osl_panel_coverage(&t, m, 1)
                .into_iter()
                .map(|(vk, _)| vk)
                .collect();
            vks.sort_unstable();
            assert_eq!(vks, (0..t.v).collect::<Vec<_>>());
        }
    }

    #[test]
    fn property_random_valid_topologies_cover() {
        property("osl coverage", 55, 25, |rng, _| {
            // build random valid square topology
            let root = 1 + rng.usize_below(3); // sqrt(L) in 1..=3
            let mult = 1 + rng.usize_below(3);
            let p = root * mult * root; // ensures sqrt(L)|P and L|V=P
            let l = root * root;
            let t = match Topology25d::new(ProcGrid::new(p, p).unwrap(), l) {
                Ok(t) => t,
                Err(e) => return Err(format!("unexpected invalid: {e}")),
            };
            let m = rng.usize_below(p);
            let n = rng.usize_below(p);
            let mut vks: Vec<usize> = osl_panel_coverage(&t, m, n)
                .into_iter()
                .map(|(vk, _)| vk)
                .collect();
            vks.sort_unstable();
            if vks != (0..t.v).collect::<Vec<_>>() {
                return Err(format!("p={p} l={l} panel ({m},{n}): {vks:?}"));
            }
            Ok(())
        });
    }
}
