//! PJRT artifact registry: load `artifacts/*.hlo.txt` once, compile on
//! the PJRT CPU client, execute from the rust hot path.
//!
//! Python runs only at build time (`make artifacts` →
//! `python/compile/aot.py`); this module consumes its outputs:
//! `manifest.json` describing each artifact's shapes plus one HLO **text**
//! file per variant (text, not serialized proto — xla_extension 0.5.1
//! rejects jax ≥ 0.5's 64-bit instruction ids; the text parser reassigns
//! ids).  Pattern follows /opt/xla-example/load_hlo.rs.
//!
//! Thread-safety: the CPU PJRT client wraps raw C++ pointers without Sync
//! guarantees, so a [`PjrtContext`] must stay on one thread.  The
//! distributed engines therefore run the native microkernel inside rank
//! threads, while the PJRT path serves the single-threaded drivers
//! (quickstart, kernel validation, benches) — python stays off the
//! request path either way.
//!
//! The PJRT client needs the vendored `xla` crate and the xla_extension
//! native library, which only the original build image provides.  The
//! real implementation is therefore gated behind the `pjrt` cargo
//! feature; without it this module compiles a stub whose
//! [`PjrtContext::load`] always errors, which every caller already treats
//! as "skip the PJRT path" (manifest parsing stays available either way).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: String,
    pub file: String,
    /// Stack capacity `n` for panel_multiply; panel dim for sign_step.
    pub capacity: usize,
    /// `[bm, bk, bn]`.
    pub block: [usize; 3],
}

/// Parse `manifest.json` into artifact specs.
pub fn parse_manifest(text: &str) -> anyhow::Result<Vec<ArtifactSpec>> {
    let v = Json::parse(text)?;
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("manifest not an array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for e in arr {
        let get_str = |k: &str| -> anyhow::Result<String> {
            Ok(e.get(k)
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow::anyhow!("manifest entry missing '{k}'"))?
                .to_string())
        };
        let block = e
            .get("block")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest entry missing 'block'"))?;
        anyhow::ensure!(block.len() == 3, "block must have 3 dims");
        out.push(ArtifactSpec {
            name: get_str("name")?,
            kind: get_str("kind")?,
            file: get_str("file")?,
            capacity: e
                .get("capacity")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow::anyhow!("manifest entry missing 'capacity'"))?,
            block: [
                block[0].as_usize().unwrap_or(0),
                block[1].as_usize().unwrap_or(0),
                block[2].as_usize().unwrap_or(0),
            ],
        });
    }
    Ok(out)
}

/// A compiled artifact ready to execute.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    /// The compiled PJRT executable (real builds only).
    #[cfg(feature = "pjrt")]
    pub exe: xla::PjRtLoadedExecutable,
}

/// The PJRT CPU client with every artifact compiled.  Without the `pjrt`
/// feature this is a stub that can never be constructed: `load` reports
/// why, and callers fall back to the native microkernel.
pub struct PjrtContext {
    #[cfg(feature = "pjrt")]
    pub client: xla::PjRtClient,
    artifacts: HashMap<String, LoadedArtifact>,
    dir: PathBuf,
}

impl PjrtContext {
    /// Load and compile every artifact in `dir` (default: `artifacts/`).
    #[cfg(feature = "pjrt")]
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            )
        })?;
        let specs = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu()?;
        let mut artifacts = HashMap::new();
        for spec in specs {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            artifacts.insert(spec.name.clone(), LoadedArtifact { spec, exe });
        }
        Ok(Self {
            client,
            artifacts,
            dir,
        })
    }

    /// Stub loader: always errors so callers take their native fallback.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        anyhow::bail!(
            "PJRT support is disabled: {} not loaded (add the vendored `xla` crate to \
             rust/Cargo.toml, then rebuild with `--features pjrt`; see rust/README.md)",
            dir.as_ref().display()
        )
    }

    /// Artifact directory this context was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Look up a compiled artifact by name.
    pub fn get(&self, name: &str) -> Option<&LoadedArtifact> {
        self.artifacts.get(name)
    }

    /// All loaded artifact names (sorted).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// The `panel_multiply` artifact matching a block shape, if any.
    pub fn gemm_variant(&self, bm: usize, bk: usize, bn: usize) -> Option<&LoadedArtifact> {
        self.artifacts
            .values()
            .find(|a| a.spec.kind == "panel_multiply" && a.spec.block == [bm, bk, bn])
    }

    /// The `sign_step` artifact for panel dim `n`, if any.
    pub fn sign_variant(&self, n: usize) -> Option<&LoadedArtifact> {
        self.artifacts
            .values()
            .find(|a| a.spec.kind == "sign_step" && a.spec.capacity == n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_entries() {
        let text = r#"[
          {"name": "batched_gemm_b6", "kind": "panel_multiply",
           "file": "batched_gemm_b6.hlo.txt", "capacity": 1024,
           "block": [6, 6, 6],
           "inputs": [], "outputs": []}
        ]"#;
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].capacity, 1024);
        assert_eq!(specs[0].block, [6, 6, 6]);
    }

    #[test]
    fn parse_rejects_bad_manifest() {
        assert!(parse_manifest("{}").is_err());
        assert!(parse_manifest(r#"[{"name": "x"}]"#).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_reports_disabled() {
        let err = PjrtContext::load("artifacts").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }

    // Tests that actually load artifacts live in rust/tests/runtime_pjrt.rs
    // (they need `make artifacts` and `--features pjrt` to have run).
}
