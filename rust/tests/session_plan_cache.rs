//! Integration: the planning session layer.
//!
//! Pins the PR's acceptance bar on the Hamiltonian workload: the
//! planned sign iteration prices the full candidate set at most once
//! per distinct sparsity-signature bucket (asserted via the `PlanEvent`
//! trail and the session's cache stats), and a cached run is bitwise
//! identical to the uncached (capacity-0) path.

use dbcsr::blocks::filter::FilterConfig;
use dbcsr::blocks::layout::BlockLayout;
use dbcsr::blocks::matrix::BlockCsrMatrix;
use dbcsr::engines::context::MultSession;
use dbcsr::engines::multiply::multiply_oracle;
use dbcsr::engines::planner::Planner;
use dbcsr::perfmodel::machine::MachineModel;
use dbcsr::sign::iteration::{scale_to_unit_norm, sign_iteration_session, PlannedSignResult};
use dbcsr::workloads::hamiltonian::synthetic_system;
use dbcsr::workloads::spec::BenchSpec;

fn hamiltonian_x0() -> BlockCsrMatrix {
    let sys = synthetic_system(8, 3, 7);
    let hm = sys.h.add_scaled(-sys.mu, &sys.s);
    scale_to_unit_norm(&hm).0
}

fn planner4() -> Planner {
    Planner::new(MachineModel::piz_daint(50e9), 4)
}

fn planned_sign(cache_capacity: usize, drift: f64) -> PlannedSignResult {
    let x0 = hamiltonian_x0();
    let mut session = MultSession::new(planner4(), 9).with_cache_capacity(cache_capacity);
    sign_iteration_session(&x0, &mut session, drift, 1e-9, 60).unwrap()
}

#[test]
fn cached_sign_run_bitwise_identical_to_uncached() {
    let cached = planned_sign(32, 0.25);
    let uncached = planned_sign(0, 0.25);
    assert!(cached.result.converged && uncached.result.converged);
    assert_eq!(cached.result.iters.len(), uncached.result.iters.len());
    // plans are priced on canonical (bucket-center) specs either way,
    // so both paths run the exact same configurations: bitwise-equal
    // iterates, not just numerically close ones
    assert_eq!(
        cached
            .result
            .sign
            .to_dense()
            .max_abs_diff(&uncached.result.sign.to_dense()),
        0.0
    );
    for (a, b) in cached.result.iters.iter().zip(&uncached.result.iters) {
        assert_eq!(a.delta.to_bits(), b.delta.to_bits(), "iter {}", a.iter);
        assert_eq!(a.occupancy.to_bits(), b.occupancy.to_bits());
    }
    // the uncached baseline re-prices every lookup; the cached run reuses
    assert_eq!(uncached.session.plans_reused, 0);
    assert_eq!(
        uncached.session.plans_priced,
        2 * uncached.result.iters.len()
    );
    assert!(cached.session.plans_reused > 0);
    assert!(cached.session.plans_priced < uncached.session.plans_priced);
}

#[test]
fn sign_prices_each_signature_bucket_at_most_once() {
    let out = planned_sign(32, 0.25);
    assert!(out.result.converged, "sign run did not converge");
    let s = &out.session;
    // every pricing created one cache entry; entries only leave through
    // drift invalidation (never eviction at this scale), so the full
    // enumeration ran at most once per distinct live bucket
    assert_eq!(s.cache_evictions, 0);
    assert_eq!(s.cache_entries, s.plans_priced - s.cache_invalidations);
    assert!(s.plans_reused > 0, "steady-state iterations must hit");
    // one plan-pair lookup per iteration
    assert_eq!(s.plans_priced + s.plans_reused, 2 * out.result.iters.len());
    // the X·X trail never prices one bucket twice: fresh pricings carry
    // pairwise-distinct bucket centers
    let mut seen = std::collections::BTreeSet::new();
    for ev in out.plans.iter().filter(|e| !e.cached) {
        assert!(
            seen.insert(ev.plan.spec_occupancy.to_bits()),
            "bucket {} priced twice",
            ev.plan.spec_occupancy
        );
    }
    // the trail starts with a fresh pricing
    assert!(!out.plans[0].cached);
    assert_eq!(out.plans[0].iter, 0);
    // Newton–Schulz fill-in on the banded start far exceeds the 25%
    // drift threshold, so the stale bucket was invalidated at least once
    assert!(s.cache_invalidations >= 1, "fill-in never invalidated");
    assert!(out.replans >= 1);
}

#[test]
fn drift_invalidation_reprices_stale_buckets() {
    let planner = planner4();
    let mut session = MultSession::new(planner, 1);
    let spec = BenchSpec::observed("inv", 12, 3, 0.3);
    let (_, _, hit0) = session.plan_spec(&spec).unwrap();
    let (_, _, hit1) = session.plan_spec(&spec).unwrap();
    assert!(!hit0 && hit1);
    assert!(session.invalidate_spec(&spec));
    let (_, _, hit2) = session.plan_spec(&spec).unwrap();
    assert!(!hit2, "invalidated bucket must re-price");
    let s = session.summary();
    assert_eq!(s.plans_priced, 2);
    assert_eq!(s.plans_reused, 1);
    assert_eq!(s.cache_invalidations, 1);
    assert_eq!(s.cache_entries, 1);
}

#[test]
fn joint_sequence_matches_oracle_across_occupancies() {
    let l = BlockLayout::uniform(14, 3);
    let a = BlockCsrMatrix::random(&l, &l, 0.15, 1);
    let b = BlockCsrMatrix::random(&l, &l, 0.45, 2);
    let c = BlockCsrMatrix::random(&l, &l, 0.85, 3);
    let mut session = MultSession::new(planner4(), 5);
    let pairs: [(&BlockCsrMatrix, &BlockCsrMatrix); 3] = [(&a, &b), (&c, &c), (&a, &c)];
    let runs = session.multiply_seq(&pairs).unwrap();
    assert_eq!(runs.len(), 3);
    for (run, (x, y)) in runs.iter().zip(pairs) {
        let want = multiply_oracle(x, y, None, &FilterConfig::none());
        let diff = run.report.c.to_dense().max_abs_diff(&want.to_dense());
        assert!(diff < 1e-10, "seq step diverged: {diff}");
    }
    let s = session.summary();
    assert_eq!(s.multiplications, 3);
    assert_eq!(s.seq_joint_plans, 1);
    // when the scheduler reached grid agreement, no redistribution may
    // have happened mid-sequence
    if s.grid_agreements == 2 {
        assert_eq!(s.grid_redistributions, 0);
    }
    // rebalance is off by default: the dist counter never moves
    assert_eq!(s.dist_redistributions, 0);
    assert_eq!(s.rebalance_migrated_bytes, 0);
}

#[test]
fn planned_sign_converges_under_filtering_through_session() {
    let x0 = hamiltonian_x0();
    let mut session = MultSession::new(planner4(), 9).with_filter(FilterConfig::uniform(1e-8));
    let out = sign_iteration_session(&x0, &mut session, 0.25, 1e-5, 80).unwrap();
    assert!(out.result.converged);
    // sign(A)² = I within the filtering noise floor
    let s = out.result.sign.to_dense();
    let s2 = s.matmul(&s);
    let eye = dbcsr::blocks::dense::DenseMatrix::eye(s.rows);
    assert!(s2.max_abs_diff(&eye) < 1e-3, "{}", s2.max_abs_diff(&eye));
}
