//! Multiplication engines: Cannon/PTP (Algorithm 1) and 2.5D/RMA
//! (Algorithm 2), plus the shared tick schedule, the double-buffered
//! prefetch pipeline they are both built on, the cost-model planner
//! that chooses between them per workload, and the persistent
//! multiplication session (plan cache + window pools) that amortizes
//! that choice across a sequence of multiplications.

use std::sync::Arc;

use crate::local::dispatch::KernelRegistry;

pub mod cannon;
pub mod context;
pub mod multiply;
pub mod osl;
pub mod pipeline;
pub mod plancache;
pub mod planner;
pub mod schedule;
pub mod serve;

/// Per-rank execution options shared by both engines' `run_rank`.
#[derive(Clone, Debug)]
pub struct RankOpts {
    /// On-the-fly filter threshold (Eq. 1).
    pub eps: f64,
    /// Intra-rank stack-executor worker threads.
    pub threads: usize,
    /// Structure-first communication avoidance before panel data moves.
    pub symbolic: bool,
    /// Async stack submission (one-sided engine only): release the A
    /// batch budget and stage the tick's product stacks before they
    /// execute, so tick `t+1`'s fetches fly while tick `t` computes.
    /// Cannon already posts its shifts ahead of the multiplication
    /// ([`pipeline::TickWindow`]), so the flag is a no-op there.
    pub async_submission: bool,
    /// Per-shape kernel dispatch table; `None` runs the generic
    /// microkernel for every block shape.
    pub registry: Option<Arc<KernelRegistry>>,
}

impl RankOpts {
    /// Options with the engine defaults: eager fetches, async
    /// submission on, generic kernels.
    pub fn new(eps: f64, threads: usize) -> Self {
        Self {
            eps,
            threads,
            symbolic: false,
            async_submission: true,
            registry: None,
        }
    }
}
