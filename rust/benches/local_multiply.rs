//! Bench: the node-local hot path — microkernel GEMM, batch assembly,
//! full panel products, and the PJRT/Pallas artifact path.
//!
//! ```bash
//! cargo bench --bench local_multiply
//! ```

use dbcsr::benchkit::{print_header, Bencher};
use dbcsr::blocks::build::BlockAccumulator;
use dbcsr::blocks::layout::BlockLayout;
use dbcsr::blocks::matrix::BlockCsrMatrix;
use dbcsr::local::batch::{assemble_tasks, matrix_to_panel, multiply_panels_native, LocalMultStats};
use dbcsr::local::microkernel::{gemm_acc, gemm_flops};
use dbcsr::util::prng::Pcg64;

fn main() {
    let bencher = Bencher::default();

    // --- raw microkernel at the paper's block sizes --------------------
    print_header("microkernel gemm_acc (paper block sizes)");
    let mut rng = Pcg64::new(1);
    for &s in &[6usize, 23, 32] {
        let a: Vec<f64> = (0..s * s).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..s * s).map(|_| rng.normal()).collect();
        let mut c = vec![0.0; s * s];
        let m = bencher.run(&format!("gemm {s}x{s}x{s}"), || {
            gemm_acc(s, s, s, &a, &b, &mut c);
            c[0]
        });
        println!("{}", m.row(Some((gemm_flops(s, s, s), "FLOP"))));
    }

    // --- batch assembly + full panel multiply --------------------------
    print_header("panel multiply (assembly + filter + execute)");
    for (nb, bs, occ) in [(64usize, 6usize, 0.3), (32, 23, 0.3), (24, 32, 1.0)] {
        let l = BlockLayout::uniform(nb, bs);
        let a = BlockCsrMatrix::random(&l, &l, occ, 7);
        let b = BlockCsrMatrix::random(&l, &l, occ, 8);
        let (pa, pb) = (matrix_to_panel(&a), matrix_to_panel(&b));
        let mut st = LocalMultStats::default();
        let tasks = assemble_tasks(&pa, &pb, -1.0, &mut st);
        let flops: f64 = tasks.len() as f64 * gemm_flops(bs, bs, bs);
        let m = bencher.run(&format!("panel {nb}x{nb} b{bs} occ {occ}"), || {
            let mut acc = BlockAccumulator::new();
            multiply_panels_native(&pa, &pb, -1.0, &mut acc);
            acc.nblocks()
        });
        println!("{}", m.row(Some((flops, "FLOP"))));
        let m = bencher.run(&format!("assemble-only {nb}x{nb} b{bs}"), || {
            let mut st = LocalMultStats::default();
            assemble_tasks(&pa, &pb, -1.0, &mut st).len()
        });
        println!("{}", m.row(None));
    }

    // --- PJRT / Pallas artifact path ------------------------------------
    match dbcsr::runtime::client::PjrtContext::load("artifacts") {
        Ok(ctx) => {
            print_header("AOT Pallas kernel via PJRT (f32)");
            for (nb, bs) in [(64usize, 6usize), (32, 23), (24, 32)] {
                let l = BlockLayout::uniform(nb, bs);
                let a = BlockCsrMatrix::random(&l, &l, 0.5, 9);
                let b = BlockCsrMatrix::random(&l, &l, 0.5, 10);
                let (pa, pb) = (matrix_to_panel(&a), matrix_to_panel(&b));
                let mut st = LocalMultStats::default();
                let ntasks = assemble_tasks(&pa, &pb, -1.0, &mut st).len();
                let flops = ntasks as f64 * gemm_flops(bs, bs, bs);
                let m = bencher.run(&format!("pjrt panel b{bs} ({ntasks} prods)"), || {
                    let mut acc = BlockAccumulator::new();
                    dbcsr::runtime::gemm::multiply_panels_pjrt(&ctx, &pa, &pb, -1.0, &mut acc)
                        .unwrap();
                    acc.nblocks()
                });
                println!("{}", m.row(Some((flops, "FLOP"))));
            }
        }
        Err(e) => println!("\npjrt benches skipped: {e}"),
    }
}
