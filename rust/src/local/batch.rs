//! Batch assembly + execution of one local multiplication
//! `C_panel += A_panel · B_panel` with DBCSR's on-the-fly filter.
//!
//! Block pairs are matched on the inner dimension (`A.col == B.row`) by a
//! **merge-join** over the panels' sorted CSR indices (built once at
//! panel construction — no per-call `HashMap`), their norm product is
//! tested against the filtering threshold, and the surviving products
//! flow through the stack machinery of [`crate::local::stackflow`]:
//! binned into homogeneous per-`(bm, bk, bn)` stacks and dispatched to a
//! [`StackExecutor`] — the native microkernel with an intra-rank worker
//! pool, or the AOT Pallas kernel via PJRT — which accumulates into a
//! dense [`CArena`].

use crate::blocks::arena::CArena;
use crate::blocks::build::BlockAccumulator;
use crate::blocks::panel::{CsrIndex, Panel};
use crate::local::microkernel::{gemm_acc, gemm_flops};
use crate::local::stackflow::{build_stacks, NativeStackExecutor, StackExecutor};

/// One surviving block product: indices into the A and B panels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProductTask {
    pub a_entry: usize,
    pub b_entry: usize,
}

/// Per-`(bm, bk, bn)` slice of the executed-flop histogram.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DimsFlops {
    pub bm: u16,
    pub bk: u16,
    pub bn: u16,
    /// Products executed at these dims.
    pub products: u64,
    /// FLOPs executed at these dims.
    pub flops: f64,
}

/// Statistics of one local multiplication.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LocalMultStats {
    /// Products that passed the norm filter and were executed.
    pub products: u64,
    /// Products skipped by the on-the-fly filter.
    pub filtered: u64,
    /// FLOPs actually executed.
    pub flops: f64,
    /// Homogeneous stacks dispatched to an executor.
    pub stacks: u64,
    /// Dispatch slots of those stacks (`stacks × capacity`); the packed
    /// PJRT path pads to its artifact capacity, the native path batches
    /// at [`crate::local::stackflow::STACK_CAPACITY`].
    pub stack_slots: u64,
    /// Executed-flop histogram per block-product dims, sorted by
    /// `(bm, bk, bn)`.
    pub by_dims: Vec<DimsFlops>,
    /// Per-rank executed flops, in rank order — populated by the
    /// distributed driver (one entry per rank); empty on single-rank
    /// local runs.  The basis of the load-imbalance observability in
    /// reports and of the rebalance stage's before/after accounting.
    pub rank_flops: Vec<f64>,
}

impl LocalMultStats {
    pub fn merge(&mut self, other: &LocalMultStats) {
        self.products += other.products;
        self.filtered += other.filtered;
        self.flops += other.flops;
        self.stacks += other.stacks;
        self.stack_slots += other.stack_slots;
        for d in &other.by_dims {
            self.record_dims(d.bm, d.bk, d.bn, d.products, d.flops);
        }
        self.rank_flops.extend_from_slice(&other.rank_flops);
    }

    /// Max/mean ratio of the per-rank executed flops (1.0 = perfectly
    /// balanced; also 1.0 when the histogram is absent or all-zero).
    pub fn flop_imbalance(&self) -> f64 {
        if self.rank_flops.is_empty() {
            return 1.0;
        }
        let mean = self.rank_flops.iter().sum::<f64>() / self.rank_flops.len() as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        self.rank_flops.iter().cloned().fold(0.0, f64::max) / mean
    }

    /// Fold `products` executed products of shape `bm×bk×bn` into the
    /// histogram (kept sorted by dims).
    pub fn record_dims(&mut self, bm: u16, bk: u16, bn: u16, products: u64, flops: f64) {
        match self
            .by_dims
            .binary_search_by_key(&(bm, bk, bn), |d| (d.bm, d.bk, d.bn))
        {
            Ok(i) => {
                self.by_dims[i].products += products;
                self.by_dims[i].flops += flops;
            }
            Err(i) => self.by_dims.insert(
                i,
                DimsFlops {
                    bm,
                    bk,
                    bn,
                    products,
                    flops,
                },
            ),
        }
    }

    /// Average stack fill: executed products per dispatch slot (1.0 =
    /// every dispatched stack ran full).
    pub fn stack_fill(&self) -> f64 {
        if self.stack_slots == 0 {
            0.0
        } else {
            self.products as f64 / self.stack_slots as f64
        }
    }
}

/// Enumerate the surviving products of `A_panel · B_panel`.
///
/// `eps < 0` disables the filter.  Matching merge-joins A's by-column
/// index against B's by-row index — both cached on the panels (falling
/// back to a one-off sort for hand-built panels): `O(|A| + |B| +
/// matches)` with no hashing.
pub fn assemble_tasks(
    a: &Panel,
    b: &Panel,
    eps: f64,
    stats: &mut LocalMultStats,
) -> Vec<ProductTask> {
    let a_tmp;
    let a_by_col = match a.index() {
        Some(ix) => &ix.by_col,
        None => {
            a_tmp = CsrIndex::build(a.entries.iter().map(|e| e.col));
            &a_tmp
        }
    };
    let b_tmp;
    let b_by_row = match b.index() {
        Some(ix) => &ix.by_row,
        None => {
            b_tmp = CsrIndex::build(b.entries.iter().map(|e| e.row));
            &b_tmp
        }
    };
    let mut tasks = Vec::new();
    let (mut ga, mut gb) = (0usize, 0usize);
    while ga < a_by_col.ngroups() && gb < b_by_row.ngroups() {
        let (ka, kb) = (a_by_col.key(ga), b_by_row.key(gb));
        if ka < kb {
            ga += 1;
        } else if kb < ka {
            gb += 1;
        } else {
            for &ae in a_by_col.group(ga) {
                let an = a.norms[ae as usize];
                for &be in b_by_row.group(gb) {
                    if eps < 0.0 || an * b.norms[be as usize] > eps {
                        tasks.push(ProductTask {
                            a_entry: ae as usize,
                            b_entry: be as usize,
                        });
                    } else {
                        stats.filtered += 1;
                    }
                }
            }
            ga += 1;
            gb += 1;
        }
    }
    tasks
}

/// Execute tasks one by one with the native microkernel, accumulating
/// straight into the HashMap-keyed `acc` — the **pre-stack-flow**
/// execution path, kept as an independent correctness reference and as
/// the baseline `benches/local_multiply.rs` measures the stack-flow
/// speedup against.
pub fn execute_tasks_native(
    a: &Panel,
    b: &Panel,
    tasks: &[ProductTask],
    acc: &mut BlockAccumulator,
    stats: &mut LocalMultStats,
) {
    for t in tasks {
        let aen = &a.entries[t.a_entry];
        let ben = &b.entries[t.b_entry];
        debug_assert_eq!(aen.col, ben.row, "inner dimension mismatch");
        let (m, k, n) = (aen.nr as usize, aen.nc as usize, ben.nc as usize);
        let c = acc.block_mut(aen.row, ben.col, aen.nr, ben.nc);
        gemm_acc(m, k, n, a.block(t.a_entry), b.block(t.b_entry), c);
        stats.products += 1;
        stats.flops += gemm_flops(m, k, n);
    }
}

/// Pre-refactor reference multiplication: per-call `HashMap` row index +
/// per-product HashMap accumulation (what the local layer did before the
/// stack-flow refactor).  Benchmarked against, never on the engine path.
pub fn multiply_panels_reference(
    a: &Panel,
    b: &Panel,
    eps: f64,
    acc: &mut BlockAccumulator,
) -> LocalMultStats {
    let mut stats = LocalMultStats::default();
    let b_by_row = b.index_by_row();
    let mut tasks = Vec::new();
    for (ae, aen) in a.entries.iter().enumerate() {
        if let Some(bes) = b_by_row.get(&aen.col) {
            let an = a.norms[ae];
            for &be in bes {
                if eps < 0.0 || an * b.norms[be] > eps {
                    tasks.push(ProductTask {
                        a_entry: ae,
                        b_entry: be,
                    });
                } else {
                    stats.filtered += 1;
                }
            }
        }
    }
    execute_tasks_native(a, b, &tasks, acc, &mut stats);
    stats
}

/// One-call stack-flow local multiplication: assemble (merge-join +
/// filter), bin into homogeneous stacks, execute on `exec` into a dense
/// C arena, and drain the arena into `acc`.
pub fn multiply_panels_stacked(
    a: &Panel,
    b: &Panel,
    eps: f64,
    acc: &mut BlockAccumulator,
    exec: &dyn StackExecutor,
) -> anyhow::Result<LocalMultStats> {
    let mut stats = LocalMultStats::default();
    let tasks = assemble_tasks(a, b, eps, &mut stats);
    if tasks.is_empty() {
        return Ok(stats);
    }
    let mut arena = CArena::for_pairs(a, b, tasks.iter().map(|t| (t.a_entry, t.b_entry)));
    let stacks = build_stacks(a, b, &tasks, &mut arena);
    exec.execute(a, b, &stacks, &mut arena, &mut stats)?;
    arena.drain_into(acc);
    Ok(stats)
}

/// One-call local multiplication on the native single-threaded stack
/// executor (the oracle path and the engines' `threads_per_rank = 1`
/// configuration).
pub fn multiply_panels_native(
    a: &Panel,
    b: &Panel,
    eps: f64,
    acc: &mut BlockAccumulator,
) -> LocalMultStats {
    multiply_panels_stacked(a, b, eps, acc, &NativeStackExecutor::single())
        .expect("native stack executor is infallible")
}

/// Convert a whole matrix into one panel (single-rank / oracle path).
pub fn matrix_to_panel(m: &crate::blocks::matrix::BlockCsrMatrix) -> Panel {
    let mut p = Panel::new();
    for (r, c, blk) in m.iter_blocks() {
        p.push_block(
            r as u32,
            c as u32,
            m.row_layout().size(r) as u16,
            m.col_layout().size(c) as u16,
            blk,
        );
    }
    p.with_index()
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::blocks::layout::BlockLayout;
    use crate::blocks::matrix::BlockCsrMatrix;

    #[test]
    fn panel_product_matches_dense() {
        let l = BlockLayout::uniform(8, 3);
        let a = BlockCsrMatrix::random(&l, &l, 0.4, 1);
        let b = BlockCsrMatrix::random(&l, &l, 0.4, 2);
        let mut acc = BlockAccumulator::new();
        let stats =
            multiply_panels_native(&matrix_to_panel(&a), &matrix_to_panel(&b), -1.0, &mut acc);
        assert!(stats.products > 0);
        assert_eq!(stats.filtered, 0);
        let c = acc.into_matrix(a.row_layout_arc(), b.col_layout_arc());
        let want = a.to_dense().matmul(&b.to_dense());
        assert!(c.to_dense().max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn merge_join_matches_hashmap_assembly() {
        // The merge-join assembly must enumerate exactly the products
        // the old HashMap path did (as a set), with identical filter
        // accounting — on ragged layouts and with the index cache cold.
        let l = BlockLayout::from_sizes(vec![2, 3, 1, 4, 2]);
        let a = BlockCsrMatrix::random(&l, &l, 0.7, 11);
        let b = BlockCsrMatrix::random(&l, &l, 0.7, 12);
        let (pa, pb) = (matrix_to_panel(&a), matrix_to_panel(&b));
        for eps in [-1.0, 0.4] {
            let mut s_new = LocalMultStats::default();
            let new: Vec<(usize, usize)> = assemble_tasks(&pa, &pb, eps, &mut s_new)
                .iter()
                .map(|t| (t.a_entry, t.b_entry))
                .collect();
            let mut acc = BlockAccumulator::new();
            let old_stats = multiply_panels_reference(&pa, &pb, eps, &mut acc);
            assert_eq!(new.len() as u64, old_stats.products, "eps={eps}");
            assert_eq!(s_new.filtered, old_stats.filtered, "eps={eps}");
            // cold cache (hand-built panel without reindex) agrees too
            let mut cold = pa.clone();
            cold.push_block(0, 0, 2, 2, &[0.0; 4]); // invalidate, zero block
            let mut s_cold = LocalMultStats::default();
            let cold_tasks = assemble_tasks(&cold, &pb, eps, &mut s_cold);
            assert!(cold.index().is_none());
            assert!(cold_tasks.len() >= new.len());
        }
    }

    #[test]
    fn stacked_equals_reference_numerically() {
        let l = BlockLayout::from_sizes(vec![3, 2, 3, 1, 2, 3]);
        let a = BlockCsrMatrix::random(&l, &l, 0.6, 21);
        let b = BlockCsrMatrix::random(&l, &l, 0.6, 22);
        let (pa, pb) = (matrix_to_panel(&a), matrix_to_panel(&b));
        let mut acc_new = BlockAccumulator::new();
        let s_new = multiply_panels_native(&pa, &pb, -1.0, &mut acc_new);
        let mut acc_old = BlockAccumulator::new();
        let s_old = multiply_panels_reference(&pa, &pb, -1.0, &mut acc_old);
        assert_eq!(s_new.products, s_old.products);
        assert_eq!(s_new.flops, s_old.flops);
        let c_new = acc_new.into_matrix(a.row_layout_arc(), b.col_layout_arc());
        let c_old = acc_old.into_matrix(a.row_layout_arc(), b.col_layout_arc());
        assert!(c_new.to_dense().max_abs_diff(&c_old.to_dense()) < 1e-12);
        // stack-flow bookkeeping is populated
        assert!(s_new.stacks > 0);
        assert!(s_new.stack_slots >= s_new.products);
        assert!(s_new.stack_fill() > 0.0 && s_new.stack_fill() <= 1.0);
        let hist_products: u64 = s_new.by_dims.iter().map(|d| d.products).sum();
        let hist_flops: f64 = s_new.by_dims.iter().map(|d| d.flops).sum();
        assert_eq!(hist_products, s_new.products);
        assert!((hist_flops - s_new.flops).abs() < 1e-9);
    }

    #[test]
    fn filter_skips_small_products() {
        let l = BlockLayout::uniform(4, 2);
        let a = BlockCsrMatrix::random(&l, &l, 1.0, 3);
        let b = BlockCsrMatrix::random(&l, &l, 1.0, 4);
        let (pa, pb) = (matrix_to_panel(&a), matrix_to_panel(&b));
        let mut s_all = LocalMultStats::default();
        let all = assemble_tasks(&pa, &pb, -1.0, &mut s_all);
        let mut s_none = LocalMultStats::default();
        let none = assemble_tasks(&pa, &pb, 1e12, &mut s_none);
        assert!(none.is_empty());
        assert_eq!(s_none.filtered as usize, all.len());
        // a median threshold keeps some, filters some
        let mut prods: Vec<f64> = all
            .iter()
            .map(|t| pa.norms[t.a_entry] * pb.norms[t.b_entry])
            .collect();
        prods.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let mid_eps = prods[prods.len() / 2];
        let mut s_mid = LocalMultStats::default();
        let mid = assemble_tasks(&pa, &pb, mid_eps, &mut s_mid);
        assert!(!mid.is_empty() && mid.len() < all.len());
    }

    #[test]
    fn filtered_equals_masked_execution() {
        // Executing with the filter == executing exactly the products
        // whose norm product exceeds eps.
        let l = BlockLayout::uniform(6, 3);
        let a = BlockCsrMatrix::random(&l, &l, 0.6, 5);
        let b = BlockCsrMatrix::random(&l, &l, 0.6, 6);
        let (pa, pb) = (matrix_to_panel(&a), matrix_to_panel(&b));
        let eps = 0.3;

        let mut acc1 = BlockAccumulator::new();
        multiply_panels_native(&pa, &pb, eps, &mut acc1);
        let c1 = acc1.into_matrix(a.row_layout_arc(), b.col_layout_arc());

        let mut acc2 = BlockAccumulator::new();
        let mut s = LocalMultStats::default();
        let all = assemble_tasks(&pa, &pb, -1.0, &mut s);
        let kept: Vec<ProductTask> = all
            .into_iter()
            .filter(|t| pa.norms[t.a_entry] * pb.norms[t.b_entry] > eps)
            .collect();
        execute_tasks_native(&pa, &pb, &kept, &mut acc2, &mut s);
        let c2 = acc2.into_matrix(a.row_layout_arc(), b.col_layout_arc());

        assert!(c1.to_dense().max_abs_diff(&c2.to_dense()) < 1e-14);
    }

    #[test]
    fn empty_panels_no_tasks() {
        let mut s = LocalMultStats::default();
        let tasks = assemble_tasks(&Panel::new(), &Panel::new(), -1.0, &mut s);
        assert!(tasks.is_empty());
        assert_eq!(s, LocalMultStats::default());
    }

    #[test]
    fn flop_imbalance_is_max_over_mean() {
        let mut s = LocalMultStats::default();
        assert_eq!(s.flop_imbalance(), 1.0, "no histogram → balanced");
        s.rank_flops = vec![0.0, 0.0];
        assert_eq!(s.flop_imbalance(), 1.0, "all-zero → balanced");
        s.rank_flops = vec![1.0, 1.0, 4.0, 2.0];
        assert!((s.flop_imbalance() - 2.0).abs() < 1e-12);
        // merging concatenates histograms in order
        let mut other = LocalMultStats::default();
        other.rank_flops = vec![8.0];
        s.merge(&other);
        assert_eq!(s.rank_flops, vec![1.0, 1.0, 4.0, 2.0, 8.0]);
    }

    #[test]
    fn flops_counted() {
        let l = BlockLayout::uniform(3, 4);
        let a = BlockCsrMatrix::random(&l, &l, 1.0, 7);
        let b = BlockCsrMatrix::random(&l, &l, 1.0, 8);
        let mut acc = BlockAccumulator::new();
        let s = multiply_panels_native(&matrix_to_panel(&a), &matrix_to_panel(&b), -1.0, &mut acc);
        // 3x3 grid of blocks, all present: 3*3*3 = 27 products of 4x4x4
        assert_eq!(s.products, 27);
        assert_eq!(s.flops, 27.0 * 2.0 * 64.0);
        // one uniform shape in the histogram
        assert_eq!(s.by_dims.len(), 1);
        assert_eq!(s.by_dims[0].products, 27);
    }
}
