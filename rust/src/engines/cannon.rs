//! Paper **Algorithm 1**: the original DBCSR multiplication — Cannon's
//! algorithm on the generalized `(P_R × P_C)` grid with the virtual
//! dimension `V = lcm(P_R, P_C)`, MPI point-to-point communication.
//!
//! Per rank `(i, j)`:
//!
//! 1. **Pre-shift** (blocking PTP): row-wise shift of the A panel set by
//!    `i` positions, column-wise shift of B by `j` — after which the
//!    resident virtual panels satisfy `vk ≡ i + j (mod P_C)` for A and
//!    `vk ≡ i + j (mod P_R)` for B.
//! 2. `V` **ticks**; at tick `t` the unique panel pair with
//!    `vk = (i + j + t) mod V` is resident and multiplied into the local
//!    C accumulation, while the whole resident sets are simultaneously
//!    forwarded one step left (A) / up (B) with `mpi_isend`/`mpi_irecv`;
//!    the `mpi_waitall` at the top of the next tick pays only the
//!    transfer residue the multiplication did not hide — §2's four
//!    temporary buffers (a comp + comm pair per matrix), realized here
//!    as a [`TickWindow`] over a [`BufferPool`] of four slots.
//!
//! The per-tick message is a rank's full resident set (`V/P_C` A panels,
//! `V/P_R` B panels), so each process communicates `V·|A|/P + V·|B|/P`
//! bytes in total — the `O(1/√P)` scaling of §2.  Each tick records the
//! **measured** non-overlapped wait residue from the fabric's virtual
//! clock next to the priced transfer time, which is what the paper's
//! `mpi_waitall` timer region reports.

use std::collections::HashMap;

use crate::blocks::build::BlockAccumulator;
use crate::blocks::panel::Panel;
use crate::blocks::symbolic::{
    decode_norm_ceiling, encode_norm_ceiling, filter_panel_by, survives_ceiling,
};
use crate::comm::ptp::Request;
use crate::comm::world::{Comm, Payload, TrafficClass};
use crate::dist::distribution::Distribution2d;
use crate::dist::topology25d::Topology25d;
use crate::engines::pipeline::{BufferPool, TickWindow};
use crate::engines::schedule::cannon_vk;
use crate::engines::RankOpts;
use crate::local::batch::{multiply_panels_stacked, LocalMultStats};
use crate::local::stackflow::NativeStackExecutor;
use crate::perfmodel::virtual_time::{EngineKind, RankLog, TickRecord};
use crate::stats::timers::Timers;

/// Message tags (high byte = kind, low bits = tick).
const TAG_PRE_A: u64 = 1 << 56;
const TAG_PRE_B: u64 = 2 << 56;
const TAG_A: u64 = 3 << 56;
const TAG_B: u64 = 4 << 56;

/// Per-rank result of one multiplication.
pub struct RankOutput {
    /// This rank's accumulated C contributions (its own C panel).
    pub c_acc: BlockAccumulator,
    pub mult_stats: LocalMultStats,
    pub timers: Timers,
    pub log: RankLog,
    /// Peak bytes across the four comp/comm set buffers (§2's temporary
    /// buffer inventory, measured on the executed pipeline).
    pub peak_buffer_bytes: u64,
    /// A+B wire bytes the *eager* path receives for this rank's
    /// circulation: `V` copies of the rank's own (unfiltered) panel
    /// share.  Computable locally because the sets circulate intact.
    pub eager_fetch_bytes: u64,
    /// Virtual seconds this rank blocked in the structure-exchange
    /// phase (0 in eager mode).
    pub structure_wait_s: f64,
}

/// Inputs handed to each rank: its initial panel shares.
pub struct RankInput {
    /// A panels keyed by `vk` (initially those with `vk ≡ j (mod P_C)`).
    pub a_panels: HashMap<u64, Panel>,
    /// B panels keyed by `vk` (initially those with `vk ≡ i (mod P_R)`).
    pub b_panels: HashMap<u64, Panel>,
}

fn panelset_bytes(set: &HashMap<u64, Panel>) -> u64 {
    set.values().map(|p| 8 + p.wire_bytes() as u64).sum()
}

/// Run Algorithm 1 on one rank.  `opts.eps` is the on-the-fly filter
/// threshold; `opts.threads` sizes the intra-rank stack-executor worker
/// pool; `opts.registry` routes every stack to its autotuned kernel
/// variant.  With `opts.symbolic` set, a norm-ceiling reduction runs
/// before the pre-shift and globally dead blocks are dropped from the
/// circulating sets — same surviving task stream, bitwise-identical C.
/// `opts.async_submission` is a no-op here: the shifts already post
/// ahead of the multiplication through the [`TickWindow`].
pub fn run_rank(
    comm: &Comm,
    dist: &Distribution2d,
    topo: &Topology25d,
    mut input: RankInput,
    opts: &RankOpts,
) -> RankOutput {
    let (eps, symbolic) = (opts.eps, opts.symbolic);
    let grid = &dist.grid;
    let (i, j) = grid.coords(comm.rank());
    let v = topo.v;
    let mut exec = NativeStackExecutor::new(opts.threads);
    if let Some(reg) = &opts.registry {
        exec = exec.with_registry(reg.clone());
    }
    let mut timers = Timers::new();
    let mut log = RankLog::new(EngineKind::Ptp);
    let mut mult_stats = LocalMultStats::default();
    // Canonical C accumulation: one accumulator per inner virtual index,
    // folded in ascending-vk order at the end.  A single accumulator
    // would sum the ticks in schedule order — a rotation of [0, V)
    // starting at (i + j) mod V — making C's bits depend on *which* rank
    // owns each block; per-vk accumulation makes the result a pure
    // function of the operands, so a rebalanced distribution reproduces
    // C bitwise (see `dist/rebalance.rs`).
    let mut c_accs: Vec<BlockAccumulator> = (0..v).map(|_| BlockAccumulator::new()).collect();

    // The eager path circulates the initial panel sets intact, so this
    // rank's eager receive volume is exactly `V` copies of its own
    // share — computable locally from the *unfiltered* input.
    let eager_fetch_bytes =
        (v as u64) * (panelset_bytes(&input.a_panels) + panelset_bytes(&input.b_panels));

    // --- Symbolic pass (structure-only exchange) ---------------------
    // PTP forwarding moves whole sets, so block-granular fetching is not
    // available here; instead the ranks agree on *global norm ceilings*
    // per inner block index k: an A block `(r, k)` can contribute a
    // surviving product on SOME rank only if a B block in inner row `k`
    // exists anywhere whose norm clears Eq. 1 against it (and vice
    // versa).  The predicate is rank-independent, so dropping dead
    // blocks before the pre-shift shrinks every forwarded copy while
    // leaving the surviving task stream — and the accumulation order —
    // untouched on every rank.
    let mut structure_wait_s = 0.0;
    if symbolic {
        let _ = comm.take_wait_epoch();
        timers.time("cannon/structure_exchange", || {
            let nk = dist.nbinner();
            let mut loc_a = vec![0u64; nk];
            let mut loc_b = vec![0u64; nk];
            for p in input.a_panels.values() {
                for (e, &norm) in p.entries.iter().zip(&p.norms) {
                    let k = e.col as usize;
                    loc_a[k] = loc_a[k].max(encode_norm_ceiling(norm));
                }
            }
            for p in input.b_panels.values() {
                for (e, &norm) in p.entries.iter().zip(&p.norms) {
                    let k = e.row as usize;
                    loc_b[k] = loc_b[k].max(encode_norm_ceiling(norm));
                }
            }
            // One u64 max-allreduce per inner index and matrix: the
            // presence tag + norm bits encoding makes `max` the norm
            // maximum over all ranks (absent = 0 loses to any present).
            let gmax_a: Vec<u64> = loc_a.iter().map(|&x| comm.allreduce_max(x)).collect();
            let gmax_b: Vec<u64> = loc_b.iter().map(|&x| comm.allreduce_max(x)).collect();
            comm.note_structure_exchange(2 * nk * 8);
            for p in input.a_panels.values_mut() {
                *p = filter_panel_by(p, |e, n| {
                    survives_ceiling(n, decode_norm_ceiling(gmax_b[e.col as usize]), eps)
                });
            }
            for p in input.b_panels.values_mut() {
                *p = filter_panel_by(p, |e, n| {
                    survives_ceiling(n, decode_norm_ceiling(gmax_a[e.row as usize]), eps)
                });
            }
        });
        structure_wait_s = comm.take_wait_epoch();
    }

    // --- Pre-shift (blocking point-to-point) -------------------------
    // Row-wise shift of A by i: our set goes to (i, j - i); we receive
    // the set of (i, j + i).  Column-wise shift of B by j likewise.
    let (mut comp_a, mut comp_b) = timers.time("cannon/pre_shift", || {
        let a_dest = grid.rank(i, (j + grid.cols() - i % grid.cols()) % grid.cols());
        let b_dest = grid.rank((i + grid.rows() - j % grid.rows()) % grid.rows(), j);
        let sa = comm.isend(
            a_dest,
            TAG_PRE_A,
            TrafficClass::MatrixA,
            Payload::PanelSet(input.a_panels.into_iter().collect()),
        );
        let sb = comm.isend(
            b_dest,
            TAG_PRE_B,
            TrafficClass::MatrixB,
            Payload::PanelSet(input.b_panels.into_iter().collect()),
        );
        let a_src = grid.rank(i, (j + i) % grid.cols());
        let b_src = grid.rank((i + j) % grid.rows(), j);
        let ra = comm.irecv(a_src, TAG_PRE_A, TrafficClass::MatrixA);
        let rb = comm.irecv(b_src, TAG_PRE_B, TrafficClass::MatrixB);
        let mut got = comm.wait_all(vec![sa, sb, ra, rb]);
        let mut take = || {
            got.pop()
                .unwrap()
                .unwrap()
                .into_panel_set()
                .into_iter()
                .collect()
        };
        let b: HashMap<u64, Panel> = take();
        let a: HashMap<u64, Panel> = take();
        (a, b)
    });
    log.pre_bytes = panelset_bytes(&comp_a) + panelset_bytes(&comp_b);
    log.pre_msgs = 2;
    log.pre_wait_s = comm.take_wait_epoch();

    // §2's four temporary buffers: a comp + comm set pair per matrix.
    // The comp pair holds the sets being multiplied; the comm pair is
    // claimed while a shift is in flight (the receive targets) and the
    // pairs swap at the waitall — so all four coexist exactly when the
    // arrivals land next to the still-live comp sets, which is the peak
    // the pool series records.
    let mut pool = BufferPool::new("cannon/set_buffers", 4);
    let (mut cur_a_bytes, mut cur_b_bytes) = (panelset_bytes(&comp_a), panelset_bytes(&comp_b));
    pool.acquire(cur_a_bytes);
    pool.acquire(cur_b_bytes);
    let mut shifts: TickWindow<Vec<Request>> = TickWindow::new();

    // --- V ticks ------------------------------------------------------
    for t in 0..v {
        // mpi_waitall: the previous tick's shifts must have completed;
        // only the residue the multiplication did not hide is paid.
        if let Some(reqs) = shifts.claim(t) {
            let arrivals = timers.time("cannon/mpi_waitall", || comm.wait_all(reqs));
            let mut rec = TickRecord::default();
            for payload in arrivals.into_iter().flatten() {
                let set = payload.into_panel_set();
                let bytes: u64 = set.iter().map(|(_, p)| 8 + p.wire_bytes() as u64).sum();
                // A sets come from the right (same row), B from below; we
                // distinguish by reassembling in tag order: first is A.
                // Pricing follows the sender's fabric level.
                let src = if rec.a_msgs == 0 {
                    let (ri, rj) = grid.right(i, j);
                    grid.rank(ri, rj)
                } else {
                    let (di, dj) = grid.down(i, j);
                    grid.rank(di, dj)
                };
                rec.comm_s += comm.price_ptp_from(src, bytes as usize);
                if rec.a_msgs == 0 {
                    rec.a_bytes = bytes;
                    rec.a_msgs = 1;
                    comp_a = set.into_iter().collect();
                } else {
                    rec.b_bytes = bytes;
                    rec.b_msgs = 1;
                    comp_b = set.into_iter().collect();
                }
            }
            // Swap comm -> comp: the arrivals coexist with the old comp
            // sets for a moment (the four-buffer peak), then the old
            // pair is dropped.
            pool.release(0);
            pool.release(0);
            pool.acquire(rec.a_bytes);
            pool.acquire(rec.b_bytes);
            pool.release(cur_a_bytes);
            pool.release(cur_b_bytes);
            (cur_a_bytes, cur_b_bytes) = (rec.a_bytes, rec.b_bytes);
            rec.wait_s = comm.take_wait_epoch();
            log.ticks.push(rec);
        } else {
            log.ticks.push(TickRecord::default());
        }

        // Start next tick's shifts (overlapped with the multiplication):
        // claim the comm buffer pair the arrivals will land in.
        if t + 1 < v {
            let (li, lj) = grid.left(i, j);
            let (ui, uj) = grid.up(i, j);
            pool.acquire(0);
            pool.acquire(0);
            let sa = comm.isend(
                grid.rank(li, lj),
                TAG_A | (t as u64),
                TrafficClass::MatrixA,
                Payload::PanelSet(comp_a.iter().map(|(k, p)| (*k, p.clone())).collect()),
            );
            let sb = comm.isend(
                grid.rank(ui, uj),
                TAG_B | (t as u64),
                TrafficClass::MatrixB,
                Payload::PanelSet(comp_b.iter().map(|(k, p)| (*k, p.clone())).collect()),
            );
            let (ri, rj) = grid.right(i, j);
            let (di, dj) = grid.down(i, j);
            let ra = comm.irecv(grid.rank(ri, rj), TAG_A | (t as u64), TrafficClass::MatrixA);
            let rb = comm.irecv(grid.rank(di, dj), TAG_B | (t as u64), TrafficClass::MatrixB);
            shifts.stash(t + 1, vec![sa, sb, ra, rb]);
        }

        // Local multiplication of the aligned panel pair (its virtual
        // compute time is what hides the in-flight shift).
        let vk = cannon_vk(topo, i, j, t);
        let (pa, pb) = (comp_a.get(&(vk as u64)), comp_b.get(&(vk as u64)));
        if let (Some(pa), Some(pb)) = (pa, pb) {
            let s = timers.time("cannon/local_multiply", || {
                multiply_panels_stacked(pa, pb, eps, &mut c_accs[vk], &exec)
                    .expect("native stack executor is infallible")
            });
            comm.advance_compute_flops(s.flops);
            mult_stats.merge(&s);
            log.ticks.last_mut().unwrap().flops += s.flops;
        }
    }
    // Ascending-vk fold into the rank's C panel (the canonical order).
    let mut c_acc = BlockAccumulator::new();
    for acc in c_accs {
        if !acc.is_empty() {
            c_acc.add_panel(&acc.into_panel());
        }
    }
    // t == v-1 posts no shift, so nothing is left in flight after the
    // loop: every stash(t+1) with t+1 <= v-1 was claimed at tick t+1.
    RankOutput {
        c_acc,
        mult_stats,
        timers,
        log,
        peak_buffer_bytes: pool.peak_bytes(),
        eager_fetch_bytes,
        structure_wait_s,
    }
}

#[cfg(test)]
mod tests {
    // Engine-level equality tests live in engines::multiply (they need
    // the full driver); here we test rank-local pieces.
    use super::*;

    #[test]
    fn panelset_bytes_counts_keys() {
        let mut set = HashMap::new();
        let mut p = Panel::new();
        p.push_block(0, 0, 1, 1, &[1.0]);
        set.insert(3u64, p);
        assert_eq!(panelset_bytes(&set), 8 + (8 + 16 + 8));
    }
}
