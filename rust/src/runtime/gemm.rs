//! Execute the AOT Pallas batched-GEMM (and sign-step) artifacts.
//!
//! The L3 side of the three-layer contract, unified behind the
//! stack-flow seam: [`PjrtStackExecutor`] implements
//! [`StackExecutor`](crate::local::stackflow::StackExecutor), so the
//! same homogeneous stacks the native worker pool consumes are packed
//! (`local/stacks.rs`) into the kernel's static `[N, bm, bk]` shape,
//! run through the compiled PJRT executable and scattered into the dense
//! C arena — falling back to the native microkernel for shapes with no
//! matching AOT variant.  The executor is single-threaded by design: the
//! CPU PJRT client is not thread-safe (see `runtime/client.rs`), so
//! `threads_per_rank > 1` belongs to the native executor only.
//!
//! Without the `pjrt` cargo feature the executors below return an error
//! unconditionally — consistent with the stub [`PjrtContext`], which can
//! never be constructed in that configuration.

use std::cell::RefCell;

use crate::blocks::arena::CArena;
use crate::blocks::build::BlockAccumulator;
use crate::blocks::panel::Panel;
use crate::local::batch::{multiply_panels_stacked, LocalMultStats};
use crate::local::stackflow::{dispatch_slots, NativeStackExecutor, Stack, StackExecutor};
use crate::local::stacks::{scatter_results_arena, PackScratch, PackedStack};
use crate::runtime::client::PjrtContext;

/// Execute one packed stack on its AOT variant.  `eps` is the on-the-fly
/// filter threshold (f32; padding slots have zero norms, so any
/// `eps >= 0` filters them inside the kernel itself).
#[cfg(feature = "pjrt")]
pub fn execute_stack(
    ctx: &PjrtContext,
    stack: &PackedStack,
    eps: f32,
) -> anyhow::Result<Vec<f32>> {
    let variant = ctx
        .gemm_variant(stack.bm, stack.bk, stack.bn)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no AOT variant for block shape {}x{}x{}",
                stack.bm,
                stack.bk,
                stack.bn
            )
        })?;
    anyhow::ensure!(
        stack.capacity == variant.spec.capacity,
        "stack capacity {} != artifact capacity {}",
        stack.capacity,
        variant.spec.capacity
    );
    let n = stack.capacity as i64;
    let (bm, bk, bn) = (stack.bm as i64, stack.bk as i64, stack.bn as i64);
    let a = xla::Literal::vec1(&stack.a).reshape(&[n, bm, bk])?;
    let b = xla::Literal::vec1(&stack.b).reshape(&[n, bk, bn])?;
    let e = xla::Literal::vec1(&[eps]).reshape(&[1, 1])?;
    let result = variant.exe.execute::<xla::Literal>(&[a, b, e])?[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
    let out = result.to_tuple1()?;
    Ok(out.to_vec::<f32>()?)
}

/// Stub executor: the `pjrt` feature is off, so no artifact can run.
#[cfg(not(feature = "pjrt"))]
pub fn execute_stack(
    _ctx: &PjrtContext,
    _stack: &PackedStack,
    _eps: f32,
) -> anyhow::Result<Vec<f32>> {
    anyhow::bail!("PJRT support is disabled (vendor `xla` and rebuild with `--features pjrt`)")
}

/// The AOT-kernel stack executor: every homogeneous stack with a
/// matching artifact variant runs on the Pallas kernel (packed f32,
/// padded to the artifact capacity), everything else falls back to the
/// single-threaded native microkernel — both into the same dense C
/// arena.
pub struct PjrtStackExecutor<'a> {
    pub ctx: &'a PjrtContext,
    /// Session-held packing scratch: the pack staging buffers of every
    /// dispatch are reused instead of freshly allocated per stack
    /// (`RefCell`: `execute` takes `&self` through the trait).
    scratch: RefCell<PackScratch>,
}

impl<'a> PjrtStackExecutor<'a> {
    pub fn new(ctx: &'a PjrtContext) -> Self {
        Self {
            ctx,
            scratch: RefCell::new(PackScratch::default()),
        }
    }

    /// `(grows, reuses)` of the packing scratch — the benches assert the
    /// steady state packs without allocating.
    pub fn scratch_stats(&self) -> (u64, u64) {
        let s = self.scratch.borrow();
        (s.grows, s.reuses)
    }
}

impl StackExecutor for PjrtStackExecutor<'_> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute(
        &self,
        a: &Panel,
        b: &Panel,
        stacks: &[Stack],
        arena: &mut CArena,
        stats: &mut LocalMultStats,
    ) -> anyhow::Result<()> {
        for stack in stacks {
            let (bm, bk, bn) = (stack.bm as usize, stack.bk as usize, stack.bn as usize);
            match self.ctx.gemm_variant(bm, bk, bn) {
                Some(variant) => {
                    let cap = variant.spec.capacity;
                    let (dispatches, slots) = dispatch_slots(stack.len(), cap);
                    stats.stacks += dispatches;
                    stats.stack_slots += slots;
                    let mut scratch = self.scratch.borrow_mut();
                    for chunk in stack.entries.chunks(cap.max(1)) {
                        // The filter already ran in assemble_tasks;
                        // eps < 0 keeps every real slot, and zero
                        // padding contributes zero.
                        let ps = scratch.pack_chunk(a, b, chunk, bm, bk, bn, cap);
                        let out = execute_stack(self.ctx, ps, -1.0)?;
                        scatter_results_arena(ps, &out, arena);
                        let n = ps.len() as u64;
                        let fl = n as f64 * 2.0 * (bm * bk * bn) as f64;
                        stats.products += n;
                        stats.flops += fl;
                        stats.record_dims(stack.bm, stack.bk, stack.bn, n, fl);
                    }
                }
                None => {
                    let native = NativeStackExecutor::single();
                    native.execute(a, b, std::slice::from_ref(stack), arena, stats)?;
                }
            }
        }
        Ok(())
    }
}

/// Local multiplication `C += A_panel · B_panel` through the AOT kernel.
///
/// Stack-flow with the PJRT executor: products with a matching AOT
/// variant go through the Pallas artifact in batches of its capacity;
/// ragged leftovers run on the native microkernel.  The numeric contract
/// is f32 on the kernel path (documented deviation from DBCSR's f64; the
/// validation tests bound the error).
pub fn multiply_panels_pjrt(
    ctx: &PjrtContext,
    a: &Panel,
    b: &Panel,
    eps: f64,
    acc: &mut BlockAccumulator,
) -> anyhow::Result<LocalMultStats> {
    multiply_panels_stacked(a, b, eps, acc, &PjrtStackExecutor::new(ctx))
}

/// One dense sign-iteration step `X ← ½ X (3I − X²)` on the AOT artifact.
#[cfg(feature = "pjrt")]
pub fn sign_step_pjrt(ctx: &PjrtContext, n: usize, x: &[f32]) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(x.len() == n * n, "x must be {n}x{n}");
    let variant = ctx
        .sign_variant(n)
        .ok_or_else(|| anyhow::anyhow!("no sign_step artifact for n={n}"))?;
    let lit = xla::Literal::vec1(x).reshape(&[n as i64, n as i64])?;
    let result = variant.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
    Ok(result.to_tuple1()?.to_vec::<f32>()?)
}

/// Stub sign step: the `pjrt` feature is off.
#[cfg(not(feature = "pjrt"))]
pub fn sign_step_pjrt(_ctx: &PjrtContext, n: usize, x: &[f32]) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(x.len() == n * n, "x must be {n}x{n}");
    anyhow::bail!("PJRT support is disabled (vendor `xla` and rebuild with `--features pjrt`)")
}

// Integration tests that require built artifacts live in
// rust/tests/runtime_pjrt.rs.
