//! Stack-flow execution: homogeneous product stacks, the
//! [`StackExecutor`] abstraction and the native worker-pool executor.
//!
//! DBCSR's node-local throughput comes from *stacks*: surviving block
//! products are binned by their `(bm, bk, bn)` dims and dispatched in
//! batches to a kernel specialized for that shape (LIBSMM / LIBCUSMM,
//! paper §2; 1 rank × 8 OpenMP threads in §4's runs).  This module is
//! that machinery:
//!
//! * [`build_stacks`] bins the assembled [`ProductTask`]s into
//!   homogeneous [`Stack`]s whose entries carry precomputed dense-arena
//!   coordinates — the C-block lookup leaves the inner loop;
//! * [`StackExecutor`] is the dispatch seam both backends implement:
//!   [`NativeStackExecutor`] drives the portable microkernel, with an
//!   intra-rank worker pool when `threads > 1`; the PJRT/Pallas path
//!   (`runtime::gemm::PjrtStackExecutor`) packs the same stacks into the
//!   AOT kernel's fixed shape;
//! * the worker partition is **by arena row**: every C block belongs to
//!   exactly one worker (`arena_row % threads`), so workers write
//!   disjoint `&mut` row views of the arena — lock-free by construction,
//!   and the per-block accumulation order is independent of the thread
//!   count (results are bitwise reproducible across `threads`).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::blocks::arena::{ArenaGeometry, CArena};
use crate::blocks::panel::Panel;
use crate::local::batch::{LocalMultStats, ProductTask};
use crate::local::dispatch::{KernelFn, KernelRegistry};
use crate::local::microkernel::{gemm_acc, gemm_flops};

/// Nominal dispatch batch size of the native path (DBCSR's stack size):
/// a stack with more entries counts as multiple dispatches in the
/// stack-fill statistics.
pub const STACK_CAPACITY: usize = 1024;

/// One product inside a homogeneous stack: panel entries plus the
/// precomputed arena coordinates of the target C block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StackEntry {
    /// Index into the A panel's entries.
    pub a_entry: u32,
    /// Index into the B panel's entries.
    pub b_entry: u32,
    /// Arena row of the target C block.
    pub ri: u32,
    /// Arena col of the target C block.
    pub ci: u32,
}

/// A batch of block products sharing one `(bm, bk, bn)` shape.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Stack {
    pub bm: u16,
    pub bk: u16,
    pub bn: u16,
    pub entries: Vec<StackEntry>,
}

impl Stack {
    /// FLOPs of one product of this shape.
    pub fn flops_per_product(&self) -> f64 {
        gemm_flops(self.bm as usize, self.bk as usize, self.bn as usize)
    }

    /// Total FLOPs of the stack.
    pub fn flops(&self) -> f64 {
        self.entries.len() as f64 * self.flops_per_product()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Bin the assembled tasks into homogeneous stacks (sorted by dims for
/// determinism), resolving each task's C target to arena coordinates and
/// marking those blocks touched.
pub fn build_stacks(
    a: &Panel,
    b: &Panel,
    tasks: &[ProductTask],
    arena: &mut CArena,
) -> Vec<Stack> {
    let mut bins: BTreeMap<(u16, u16, u16), Vec<StackEntry>> = BTreeMap::new();
    for t in tasks {
        let aen = &a.entries[t.a_entry];
        let ben = &b.entries[t.b_entry];
        debug_assert_eq!(aen.col, ben.row, "inner dimension mismatch");
        let (ri, ci) = arena
            .geometry()
            .locate(aen.row, ben.col)
            .expect("task target outside the C arena");
        arena.mark(ri, ci);
        let entry = StackEntry {
            a_entry: t.a_entry as u32,
            b_entry: t.b_entry as u32,
            ri: ri as u32,
            ci: ci as u32,
        };
        bins.entry((aen.nr, aen.nc, ben.nc)).or_default().push(entry);
    }
    bins.into_iter()
        .map(|((bm, bk, bn), entries)| Stack {
            bm,
            bk,
            bn,
            entries,
        })
        .collect()
}

/// Number of kernel dispatches and padded dispatch slots for a stack of
/// `len` products batched at `capacity`: `ceil(len / capacity)`
/// dispatches, *every* dispatch padded to the full capacity — including
/// the last partial one.  This is the exact per-dispatch accounting the
/// `stack_fill` statistic divides by, shared by the native path
/// ([`STACK_CAPACITY`]) and the packed PJRT path (artifact capacity).
pub fn dispatch_slots(len: usize, capacity: usize) -> (u64, u64) {
    if len == 0 || capacity == 0 {
        return (0, 0);
    }
    let dispatches = ((len + capacity - 1) / capacity) as u64;
    (dispatches, dispatches * capacity as u64)
}

/// Split each stack's entries by C-block owner (`ri % workers`),
/// preserving entry order within each part — the partition that lets
/// workers share nothing.
pub fn partition_stacks(stacks: &[Stack], workers: usize) -> Vec<Vec<Stack>> {
    let mut parts: Vec<Vec<Stack>> = (0..workers).map(|_| Vec::new()).collect();
    for s in stacks {
        let mut split: Vec<Vec<StackEntry>> = (0..workers).map(|_| Vec::new()).collect();
        for e in &s.entries {
            split[e.ri as usize % workers].push(*e);
        }
        for (part, entries) in parts.iter_mut().zip(split) {
            if !entries.is_empty() {
                part.push(Stack {
                    bm: s.bm,
                    bk: s.bk,
                    bn: s.bn,
                    entries,
                });
            }
        }
    }
    parts
}

/// A backend that executes homogeneous stacks into the dense C arena.
///
/// Implementations: [`NativeStackExecutor`] (portable microkernel,
/// intra-rank worker pool) and `runtime::gemm::PjrtStackExecutor` (AOT
/// Pallas kernel via PJRT, single-threaded — the CPU PJRT client is not
/// thread-safe).
pub trait StackExecutor {
    /// Execute every stack, accumulating into `arena` and folding
    /// products/FLOPs/stack-fill accounting into `stats`.
    fn execute(
        &self,
        a: &Panel,
        b: &Panel,
        stacks: &[Stack],
        arena: &mut CArena,
        stats: &mut LocalMultStats,
    ) -> anyhow::Result<()>;

    /// Backend label for reports.
    fn name(&self) -> &'static str;
}

/// The native microkernel executor with a configurable intra-rank worker
/// pool (`threads = 1` runs inline, no spawning).
///
/// The pool is realized as scoped threads spawned per `execute` call:
/// the per-tick spawn/join cost (microseconds) is small against the
/// per-tick GEMM work it parallelizes, and scoped borrows keep the
/// panels/arena lock-free.  A persistent per-rank pool is the obvious
/// next step if profiles ever show the spawn cost at small tick sizes.
#[derive(Clone, Debug)]
pub struct NativeStackExecutor {
    /// Worker threads per rank (clamped to ≥ 1).
    pub threads: usize,
    /// Per-shape autotuned dispatch table; `None` runs every stack
    /// through the generic microkernel.
    pub registry: Option<Arc<KernelRegistry>>,
}

impl NativeStackExecutor {
    pub fn new(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
            registry: None,
        }
    }

    /// The single-threaded configuration (oracle / default engine path).
    pub fn single() -> Self {
        Self::new(1)
    }

    /// Dispatch stacks through the given per-shape kernel registry
    /// (autotuned on first use) instead of the generic microkernel.
    pub fn with_registry(mut self, registry: Arc<KernelRegistry>) -> Self {
        self.registry = Some(registry);
        self
    }
}

/// Resolve the kernel body for one stack: the registry's tuned choice,
/// or the generic microkernel when dispatch is off.
fn resolve_kernel(registry: Option<&KernelRegistry>, s: &Stack) -> KernelFn {
    match registry {
        Some(reg) => reg.select(s.bm as usize, s.bk as usize, s.bn as usize).kernel,
        None => gemm_acc,
    }
}

/// One worker's execution state: the panels, the shared arena geometry
/// and the disjoint arena-row views it owns (`views[r]` is arena row
/// `r * stride + worker`).
struct Worker<'p, 'v> {
    a: &'p Panel,
    b: &'p Panel,
    geom: &'p ArenaGeometry,
    views: Vec<&'v mut [f64]>,
    stride: usize,
    worker: usize,
}

impl Worker<'_, '_> {
    /// Execute one stack through `kernel`; returns the wall-clock
    /// seconds spent in the entry loop when `timed` (0.0 otherwise).
    fn run(
        &mut self,
        stack: &Stack,
        kernel: KernelFn,
        timed: bool,
        stats: &mut LocalMultStats,
    ) -> f64 {
        if stack.is_empty() {
            return 0.0;
        }
        let t0 = if timed { Some(Instant::now()) } else { None };
        let (bm, bk, bn) = (stack.bm as usize, stack.bk as usize, stack.bn as usize);
        let per = stack.flops_per_product();
        for e in &stack.entries {
            let ri = e.ri as usize;
            debug_assert_eq!(ri % self.stride, self.worker, "entry on wrong worker");
            let off = self.geom.offset_in_row(ri, e.ci as usize);
            kernel(
                bm,
                bk,
                bn,
                self.a.block(e.a_entry as usize),
                self.b.block(e.b_entry as usize),
                &mut self.views[ri / self.stride][off..off + bm * bn],
            );
        }
        let n = stack.len() as u64;
        stats.products += n;
        stats.flops += n as f64 * per;
        stats.record_dims(stack.bm, stack.bk, stack.bn, n, n as f64 * per);
        t0.map_or(0.0, |t| t.elapsed().as_secs_f64())
    }
}

impl StackExecutor for NativeStackExecutor {
    fn name(&self) -> &'static str {
        "native"
    }

    fn execute(
        &self,
        a: &Panel,
        b: &Panel,
        stacks: &[Stack],
        arena: &mut CArena,
        stats: &mut LocalMultStats,
    ) -> anyhow::Result<()> {
        // Dispatch accounting on the *pre-partition* stacks, so the
        // stack-fill statistics are independent of the worker count;
        // every dispatch is padded to STACK_CAPACITY slots, including
        // the last partial one.
        let registry = self.registry.as_deref();
        let timed = registry.is_some();
        let mut per_shape: BTreeMap<(u16, u16, u16), (u64, u64)> = BTreeMap::new();
        for s in stacks {
            if s.is_empty() {
                continue;
            }
            let (dispatches, slots) = dispatch_slots(s.len(), STACK_CAPACITY);
            stats.stacks += dispatches;
            stats.stack_slots += slots;
            if timed {
                let e = per_shape.entry((s.bm, s.bk, s.bn)).or_insert((0, 0));
                e.0 += dispatches;
                e.1 += s.len() as u64;
            }
        }
        let mut exec_s: BTreeMap<(u16, u16, u16), f64> = BTreeMap::new();
        let (geom, views) = arena.split_rows();
        let t = self.threads.min(geom.nrows()).max(1);
        if t == 1 {
            let mut w = Worker {
                a,
                b,
                geom,
                views,
                stride: 1,
                worker: 0,
            };
            let mut local = LocalMultStats::default();
            for s in stacks {
                let dt = w.run(s, resolve_kernel(registry, s), timed, &mut local);
                if timed {
                    *exec_s.entry((s.bm, s.bk, s.bn)).or_insert(0.0) += dt;
                }
            }
            stats.merge(&local);
        } else {
            let parts = partition_stacks(stacks, t);
            let mut per_rows: Vec<Vec<&mut [f64]>> = (0..t).map(|_| Vec::new()).collect();
            for (ri, view) in views.into_iter().enumerate() {
                per_rows[ri % t].push(view);
            }
            type WorkerResult = (LocalMultStats, BTreeMap<(u16, u16, u16), f64>);
            let results: Vec<WorkerResult> = std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(t);
                for (worker, (part, views)) in parts.iter().zip(per_rows).enumerate() {
                    handles.push(scope.spawn(move || {
                        let mut w = Worker {
                            a,
                            b,
                            geom,
                            views,
                            stride: t,
                            worker,
                        };
                        let mut local = LocalMultStats::default();
                        let mut secs: BTreeMap<(u16, u16, u16), f64> = BTreeMap::new();
                        for s in part {
                            let dt = w.run(s, resolve_kernel(registry, s), timed, &mut local);
                            if timed {
                                *secs.entry((s.bm, s.bk, s.bn)).or_insert(0.0) += dt;
                            }
                        }
                        (local, secs)
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("stack worker panicked"))
                    .collect()
            });
            for (r, secs) in &results {
                stats.merge(r);
                for (dims, dt) in secs {
                    *exec_s.entry(*dims).or_insert(0.0) += dt;
                }
            }
        }
        if let Some(reg) = registry {
            for (dims, (dispatches, products)) in &per_shape {
                reg.record_use(
                    dims.0 as usize,
                    dims.1 as usize,
                    dims.2 as usize,
                    *dispatches,
                    *products,
                    exec_s.get(dims).copied().unwrap_or(0.0),
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::blocks::build::BlockAccumulator;
    use crate::blocks::layout::BlockLayout;
    use crate::blocks::matrix::BlockCsrMatrix;
    use crate::local::batch::{
        assemble_tasks, matrix_to_panel, multiply_panels_reference, multiply_panels_stacked,
    };

    fn ragged_panels(seed: u64) -> (BlockCsrMatrix, BlockCsrMatrix, Panel, Panel) {
        let l = BlockLayout::from_sizes(vec![2, 3, 2, 5, 1, 3, 2]);
        let a = BlockCsrMatrix::random(&l, &l, 0.6, seed);
        let b = BlockCsrMatrix::random(&l, &l, 0.6, seed ^ 0xA5);
        let (pa, pb) = (matrix_to_panel(&a), matrix_to_panel(&b));
        (a, b, pa, pb)
    }

    #[test]
    fn stacks_are_homogeneous_and_complete() {
        let (_, _, pa, pb) = ragged_panels(1);
        let mut s = LocalMultStats::default();
        let tasks = assemble_tasks(&pa, &pb, -1.0, &mut s);
        let mut arena = CArena::build(&pa, &pb);
        let stacks = build_stacks(&pa, &pb, &tasks, &mut arena);
        let total: usize = stacks.iter().map(|s| s.len()).sum();
        assert_eq!(total, tasks.len(), "every task lands in exactly one stack");
        assert!(stacks.len() > 1, "ragged layout must produce several shapes");
        for st in &stacks {
            for e in &st.entries {
                let aen = &pa.entries[e.a_entry as usize];
                let ben = &pb.entries[e.b_entry as usize];
                assert_eq!((aen.nr, aen.nc, ben.nc), (st.bm, st.bk, st.bn));
                let (row, _) = arena.geometry().row_coord(e.ri as usize);
                let (col, _) = arena.geometry().col_coord(e.ci as usize);
                assert_eq!((row, col), (aen.row, ben.col));
            }
        }
        // sorted by dims
        let dims: Vec<(u16, u16, u16)> = stacks.iter().map(|s| (s.bm, s.bk, s.bn)).collect();
        let mut sorted = dims.clone();
        sorted.sort_unstable();
        assert_eq!(dims, sorted);
    }

    #[test]
    fn partition_respects_ownership() {
        let (_, _, pa, pb) = ragged_panels(2);
        let mut s = LocalMultStats::default();
        let tasks = assemble_tasks(&pa, &pb, -1.0, &mut s);
        let mut arena = CArena::build(&pa, &pb);
        let stacks = build_stacks(&pa, &pb, &tasks, &mut arena);
        let parts = partition_stacks(&stacks, 3);
        assert_eq!(parts.len(), 3);
        let total: usize = parts.iter().flatten().map(|s| s.len()).sum();
        assert_eq!(total, tasks.len());
        for (w, part) in parts.iter().enumerate() {
            for st in part {
                for e in &st.entries {
                    assert_eq!(e.ri as usize % 3, w, "C block on the wrong worker");
                }
            }
        }
    }

    #[test]
    fn threaded_execution_matches_reference() {
        for threads in [1usize, 2, 3, 8] {
            let (a, b, pa, pb) = ragged_panels(3);
            let exec = NativeStackExecutor::new(threads);
            let mut acc = BlockAccumulator::new();
            let stats = multiply_panels_stacked(&pa, &pb, -1.0, &mut acc, &exec).unwrap();
            let mut acc_ref = BlockAccumulator::new();
            let stats_ref = multiply_panels_reference(&pa, &pb, -1.0, &mut acc_ref);
            assert_eq!(stats.products, stats_ref.products);
            assert_eq!(stats.flops, stats_ref.flops);
            // dispatch accounting is counted pre-partition: the fill
            // statistic must not depend on the worker count
            let mut acc_1t = BlockAccumulator::new();
            let single = NativeStackExecutor::single();
            let stats_1t = multiply_panels_stacked(&pa, &pb, -1.0, &mut acc_1t, &single).unwrap();
            assert_eq!(stats.stacks, stats_1t.stacks, "threads={threads}");
            assert_eq!(stats.stack_slots, stats_1t.stack_slots);
            let c = acc.into_matrix(a.row_layout_arc(), b.col_layout_arc());
            let c_ref = acc_ref.into_matrix(a.row_layout_arc(), b.col_layout_arc());
            // same per-block summation order as single-threaded stack
            // flow; vs the task-ordered reference only fp-reassociation
            // noise is possible
            assert!(
                c.to_dense().max_abs_diff(&c_ref.to_dense()) < 1e-12,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let (a, b, pa, pb) = ragged_panels(4);
        let run = |threads: usize| {
            let exec = NativeStackExecutor::new(threads);
            let mut acc = BlockAccumulator::new();
            multiply_panels_stacked(&pa, &pb, -1.0, &mut acc, &exec).unwrap();
            acc.into_matrix(a.row_layout_arc(), b.col_layout_arc())
                .to_dense()
        };
        let c1 = run(1);
        for threads in [2usize, 4, 8] {
            let ct = run(threads);
            assert_eq!(
                c1.max_abs_diff(&ct),
                0.0,
                "worker partition must preserve per-block accumulation order"
            );
        }
    }

    #[test]
    fn dispatch_slots_pad_every_dispatch() {
        assert_eq!(dispatch_slots(0, STACK_CAPACITY), (0, 0));
        assert_eq!(dispatch_slots(5, 0), (0, 0), "zero capacity dispatches nothing");
        let cap = STACK_CAPACITY as u64;
        assert_eq!(dispatch_slots(1, STACK_CAPACITY), (1, cap));
        assert_eq!(dispatch_slots(STACK_CAPACITY, STACK_CAPACITY), (1, cap));
        assert_eq!(dispatch_slots(STACK_CAPACITY + 1, STACK_CAPACITY), (2, 2 * cap));
        assert_eq!(dispatch_slots(2 * STACK_CAPACITY + 5, STACK_CAPACITY), (3, 3 * cap));
        // stack_fill divides by the padded slots of *every* dispatch,
        // the last partial one included.
        let mut s = LocalMultStats::default();
        let (dispatches, slots) = dispatch_slots(2 * STACK_CAPACITY + 5, STACK_CAPACITY);
        s.products = 2 * cap + 5;
        s.stacks = dispatches;
        s.stack_slots = slots;
        let want = (2.0 * cap as f64 + 5.0) / (3.0 * cap as f64);
        assert!((s.stack_fill() - want).abs() < 1e-12);
    }

    #[test]
    fn registry_dispatch_is_bitwise_identical_to_generic() {
        use crate::local::dispatch::KernelRegistry;
        use crate::perfmodel::machine::MachineModel;
        let l = BlockLayout::from_sizes(vec![6, 23, 32, 6, 23, 32]);
        let a = BlockCsrMatrix::random(&l, &l, 0.8, 9);
        let b = BlockCsrMatrix::random(&l, &l, 0.8, 10);
        let (pa, pb) = (matrix_to_panel(&a), matrix_to_panel(&b));
        let machine = MachineModel::piz_daint(10.0e9);
        for threads in [1usize, 4] {
            let reg = Arc::new(KernelRegistry::modeled(machine));
            let exec = NativeStackExecutor::new(threads).with_registry(reg.clone());
            let mut acc = BlockAccumulator::new();
            multiply_panels_stacked(&pa, &pb, -1.0, &mut acc, &exec).unwrap();
            let c = acc
                .into_matrix(a.row_layout_arc(), b.col_layout_arc())
                .to_dense();
            let mut acc_g = BlockAccumulator::new();
            let generic = NativeStackExecutor::new(threads);
            multiply_panels_stacked(&pa, &pb, -1.0, &mut acc_g, &generic).unwrap();
            let c_g = acc_g
                .into_matrix(a.row_layout_arc(), b.col_layout_arc())
                .to_dense();
            assert_eq!(
                c.max_abs_diff(&c_g),
                0.0,
                "specialized kernels must be bitwise identical (threads={threads})"
            );
            let report = reg.report();
            assert!(
                report.iter().any(|r| r.variant.starts_with("fixed_")),
                "paper shapes must dispatch through fixed kernels"
            );
            let products: u64 = report.iter().map(|r| r.used.products).sum();
            assert!(products > 0, "executor must record per-shape usage");
        }
    }

    #[test]
    fn executor_reports_stack_stats() {
        let (_, _, pa, pb) = ragged_panels(5);
        let exec = NativeStackExecutor::single();
        let mut acc = BlockAccumulator::new();
        let stats = multiply_panels_stacked(&pa, &pb, -1.0, &mut acc, &exec).unwrap();
        assert_eq!(exec.name(), "native");
        assert!(stats.stacks >= stats.by_dims.len() as u64);
        assert_eq!(
            stats.stack_slots,
            stats.stacks * STACK_CAPACITY as u64,
            "native dispatch pads to STACK_CAPACITY slots"
        );
    }
}
