//! Dense C arena: the hot-path accumulation target of the stack-flow
//! local multiplication.
//!
//! DBCSR's per-product cost is dominated not by the small GEMM itself but
//! by *finding* the C block to accumulate into.  The arena removes that
//! lookup from the inner loop: once per local multiplication (one tick's
//! panel product) it lays out every C block a rank can touch — the
//! distinct block rows of the A panel × the distinct block columns of
//! the B panel — contiguously in one `f64` buffer with a precomputed
//! per-(row, col) offset table.  Stack entries then carry plain offsets,
//! and the microkernel writes straight into the slab.
//!
//! The row-major block layout additionally gives the intra-rank worker
//! pool a safe partition: all blocks of one arena row are contiguous, so
//! [`CArena::split_rows`] hands out disjoint `&mut [f64]` row views and
//! the executor assigns whole rows to workers — no two workers ever
//! share a C block, and no locks are needed.
//!
//! The arena is *per-tick* scratch; [`CArena::drain_into`] folds the
//! touched blocks back into the [`BlockAccumulator`], which remains the
//! (HashMap-keyed) builder for the assembly and 2.5D-reduction edges.

use crate::blocks::build::BlockAccumulator;
use crate::blocks::panel::Panel;

/// The arena's shape: which (row, col) blocks exist and where they live
/// in the data slab.  Shared read-only by the worker threads while the
/// data is split into per-row views.
#[derive(Clone, Debug, Default)]
pub struct ArenaGeometry {
    /// Distinct C block rows `(global block row, row dim)`, ascending.
    rows: Vec<(u32, u16)>,
    /// Distinct C block cols `(global block col, col dim)`, ascending.
    cols: Vec<(u32, u16)>,
    /// Prefix sums of the col dims (`len == cols.len() + 1`).
    col_prefix: Vec<u32>,
    /// Slab offset of each arena row of blocks (`len == rows.len() + 1`).
    row_ptr: Vec<usize>,
}

impl ArenaGeometry {
    /// Number of arena rows.
    pub fn nrows(&self) -> usize {
        self.rows.len()
    }

    /// Number of arena cols.
    pub fn ncols(&self) -> usize {
        self.cols.len()
    }

    /// `(global block row, row dim)` of arena row `ri`.
    pub fn row_coord(&self, ri: usize) -> (u32, u16) {
        self.rows[ri]
    }

    /// `(global block col, col dim)` of arena col `ci`.
    pub fn col_coord(&self, ci: usize) -> (u32, u16) {
        self.cols[ci]
    }

    /// Arena coordinates of global block `(row, col)`, if present.
    pub fn locate(&self, row: u32, col: u32) -> Option<(usize, usize)> {
        let ri = self.rows.binary_search_by_key(&row, |&(r, _)| r).ok()?;
        let ci = self.cols.binary_search_by_key(&col, |&(c, _)| c).ok()?;
        Some((ri, ci))
    }

    /// Slab length of arena row `ri` (all its blocks).
    pub fn row_len(&self, ri: usize) -> usize {
        self.row_ptr[ri + 1] - self.row_ptr[ri]
    }

    /// Offset of block `(ri, ci)` *within its row view*.
    pub fn offset_in_row(&self, ri: usize, ci: usize) -> usize {
        self.rows[ri].1 as usize * self.col_prefix[ci] as usize
    }

    /// Element count of block `(ri, ci)`.
    pub fn block_len(&self, ri: usize, ci: usize) -> usize {
        self.rows[ri].1 as usize * self.cols[ci].1 as usize
    }
}

/// Dense accumulation arena for one local multiplication.
#[derive(Clone, Debug, Default)]
pub struct CArena {
    geom: ArenaGeometry,
    data: Vec<f64>,
    /// Row-major touch map (`nrows × ncols`): only touched blocks are
    /// non-zero and drained — the arena must not invent empty C blocks.
    touched: Vec<bool>,
}

/// Distinct `(key, dim)` pairs, ascending by key (dims are consistent
/// per key: they come from one block layout).
fn distinct_dims(mut v: Vec<(u32, u16)>) -> Vec<(u32, u16)> {
    v.sort_unstable();
    v.dedup_by_key(|x| x.0);
    v
}

impl CArena {
    /// Lay out the arena over the full panel tile: rows from A's
    /// distinct block rows, cols from B's distinct block cols.  The
    /// multiply hot path uses [`CArena::for_pairs`] instead, which only
    /// allocates the rows/cols the surviving products touch.
    pub fn build(a: &Panel, b: &Panel) -> CArena {
        let rows = a.entries.iter().map(|e| (e.row, e.nr)).collect();
        let cols = b.entries.iter().map(|e| (e.col, e.nc)).collect();
        Self::from_dims(rows, cols)
    }

    /// Lay out the arena for exactly the `(a_entry, b_entry)` product
    /// pairs that survived the filter: under aggressive filtering the
    /// touched row/col sets are far smaller than the full
    /// `|A rows| × |B cols|` tile, so slab size (and its zero-fill
    /// cost) stays proportional to the actual work.
    pub fn for_pairs<I>(a: &Panel, b: &Panel, pairs: I) -> CArena
    where
        I: IntoIterator<Item = (usize, usize)>,
    {
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        for (ae, be) in pairs {
            let aen = &a.entries[ae];
            rows.push((aen.row, aen.nr));
            let ben = &b.entries[be];
            cols.push((ben.col, ben.nc));
        }
        Self::from_dims(rows, cols)
    }

    fn from_dims(rows: Vec<(u32, u16)>, cols: Vec<(u32, u16)>) -> CArena {
        let rows = distinct_dims(rows);
        let cols = distinct_dims(cols);
        let mut col_prefix = Vec::with_capacity(cols.len() + 1);
        let mut acc = 0u32;
        col_prefix.push(0);
        for &(_, nc) in &cols {
            acc += nc as u32;
            col_prefix.push(acc);
        }
        let total_nc = acc as usize;
        let mut row_ptr = Vec::with_capacity(rows.len() + 1);
        let mut off = 0usize;
        row_ptr.push(0);
        for &(_, nr) in &rows {
            off += nr as usize * total_nc;
            row_ptr.push(off);
        }
        let touched = vec![false; rows.len() * cols.len()];
        let geom = ArenaGeometry {
            rows,
            cols,
            col_prefix,
            row_ptr,
        };
        CArena {
            data: vec![0.0; off],
            geom,
            touched,
        }
    }

    /// The arena's shape.
    pub fn geometry(&self) -> &ArenaGeometry {
        &self.geom
    }

    /// Mark block `(ri, ci)` as written (done during stack assembly,
    /// before the workers run).
    pub fn mark(&mut self, ri: usize, ci: usize) {
        self.touched[ri * self.geom.ncols() + ci] = true;
    }

    /// Mutable view of block `(ri, ci)`, marked touched (single-threaded
    /// accumulation paths, e.g. the PJRT scatter).
    pub fn block_mut(&mut self, ri: usize, ci: usize) -> &mut [f64] {
        self.touched[ri * self.geom.ncols() + ci] = true;
        let off = self.geom.row_ptr[ri] + self.geom.offset_in_row(ri, ci);
        let len = self.geom.block_len(ri, ci);
        &mut self.data[off..off + len]
    }

    /// Split the slab into disjoint per-arena-row mutable views (plus
    /// the shared geometry) — the partition the worker pool distributes
    /// so that no two workers share a C block.
    pub fn split_rows(&mut self) -> (&ArenaGeometry, Vec<&mut [f64]>) {
        let geom = &self.geom;
        let mut views = Vec::with_capacity(geom.nrows());
        let mut rest = self.data.as_mut_slice();
        for ri in 0..geom.nrows() {
            let (head, tail) = rest.split_at_mut(geom.row_len(ri));
            views.push(head);
            rest = tail;
        }
        (geom, views)
    }

    /// Fold every touched block into the accumulator (the hand-off from
    /// the per-tick hot path back to the HashMap-keyed builder).
    pub fn drain_into(&self, acc: &mut BlockAccumulator) {
        let ncols = self.geom.ncols();
        for ri in 0..self.geom.nrows() {
            let (row, nr) = self.geom.rows[ri];
            for ci in 0..ncols {
                if !self.touched[ri * ncols + ci] {
                    continue;
                }
                let (col, nc) = self.geom.cols[ci];
                let off = self.geom.row_ptr[ri] + self.geom.offset_in_row(ri, ci);
                let len = nr as usize * nc as usize;
                acc.add_block(row, col, nr, nc, &self.data[off..off + len]);
            }
        }
    }

    /// Slab footprint in bytes (scratch memory the stack-flow path holds
    /// per tick).
    pub fn data_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn panels() -> (Panel, Panel) {
        // A: rows {0 (nr 2), 2 (nr 3)}, inner cols {1, 4}
        let mut a = Panel::new();
        a.push_block(0, 1, 2, 2, &[1.0; 4]);
        a.push_block(2, 1, 3, 2, &[2.0; 6]);
        a.push_block(0, 4, 2, 1, &[3.0; 2]);
        // B: inner rows {1, 4}, cols {0 (nc 2), 3 (nc 1)}
        let mut b = Panel::new();
        b.push_block(1, 0, 2, 2, &[1.0; 4]);
        b.push_block(4, 3, 1, 1, &[5.0]);
        (a.with_index(), b.with_index())
    }

    #[test]
    fn geometry_from_panels() {
        let (a, b) = panels();
        let arena = CArena::build(&a, &b);
        let g = arena.geometry();
        assert_eq!(g.nrows(), 2);
        assert_eq!(g.ncols(), 2);
        assert_eq!(g.row_coord(0), (0, 2));
        assert_eq!(g.row_coord(1), (2, 3));
        assert_eq!(g.col_coord(0), (0, 2));
        assert_eq!(g.col_coord(1), (3, 1));
        // row 0: nr 2 over total nc 3 = 6 elements; row 1: 3*3 = 9
        assert_eq!(g.row_len(0), 6);
        assert_eq!(g.row_len(1), 9);
        assert_eq!(arena.data_bytes(), (6 + 9) * 8);
        assert_eq!(g.locate(2, 3), Some((1, 1)));
        assert_eq!(g.locate(1, 3), None);
        assert_eq!(g.offset_in_row(1, 1), 3 * 2);
        assert_eq!(g.block_len(1, 0), 6);
    }

    #[test]
    fn block_mut_and_drain_roundtrip() {
        let (a, b) = panels();
        let mut arena = CArena::build(&a, &b);
        arena.block_mut(0, 0).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        arena.block_mut(1, 1).copy_from_slice(&[7.0, 8.0, 9.0]);
        let mut acc = BlockAccumulator::new();
        arena.drain_into(&mut acc);
        assert_eq!(acc.nblocks(), 2, "untouched blocks must not be drained");
        let p = acc.into_panel();
        assert_eq!(p.block(0), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.block(1), &[7.0, 8.0, 9.0]);
        let coords: Vec<(u32, u32)> = p.entries.iter().map(|e| (e.row, e.col)).collect();
        assert_eq!(coords, vec![(0, 0), (2, 3)]);
    }

    #[test]
    fn split_rows_views_are_disjoint_and_complete() {
        let (a, b) = panels();
        let mut arena = CArena::build(&a, &b);
        let (geom, views) = arena.split_rows();
        assert_eq!(views.len(), geom.nrows());
        let total: usize = views.iter().map(|v| v.len()).sum();
        assert_eq!(total, 6 + 9);
        // writes through a row view land at the geometry's offsets
        let nrows = geom.nrows();
        let off = geom.offset_in_row(1, 1);
        let len = geom.block_len(1, 1);
        let mut views = views;
        views[nrows - 1][off..off + len].copy_from_slice(&[1.5, 2.5, 3.5]);
        arena.mark(1, 1);
        let mut acc = BlockAccumulator::new();
        arena.drain_into(&mut acc);
        let p = acc.into_panel();
        assert_eq!(p.block(0), &[1.5, 2.5, 3.5]);
    }

    #[test]
    fn for_pairs_allocates_only_touched_rows_and_cols() {
        let (a, b) = panels();
        // single surviving product: A entry 1 (row 2) × B entry 1 (col 3)
        let arena = CArena::for_pairs(&a, &b, [(1usize, 1usize)]);
        let g = arena.geometry();
        assert_eq!((g.nrows(), g.ncols()), (1, 1));
        assert_eq!(g.row_coord(0), (2, 3));
        assert_eq!(g.col_coord(0), (3, 1));
        assert_eq!(arena.data_bytes(), 3 * 8);
        assert_eq!(g.locate(2, 3), Some((0, 0)));
        assert_eq!(g.locate(0, 0), None, "untouched blocks are not laid out");
        // the full tile is strictly larger
        assert!(CArena::build(&a, &b).data_bytes() > arena.data_bytes());
    }

    #[test]
    fn empty_panels_empty_arena() {
        let arena = CArena::build(&Panel::new(), &Panel::new());
        assert_eq!(arena.geometry().nrows(), 0);
        assert_eq!(arena.data_bytes(), 0);
        let mut acc = BlockAccumulator::new();
        arena.drain_into(&mut acc);
        assert!(acc.is_empty());
    }
}
