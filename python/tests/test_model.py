"""L2 model graphs: shapes, numerics, sign-step convergence."""

import numpy as np
import pytest

# Skip gracefully on runners without the JAX stack (e.g. bare CI boxes).
jax = pytest.importorskip("jax", reason="model tests need jax")
pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import sign_step_ref

jax.config.update("jax_platform_name", "cpu")


class TestPanelMultiply:
    def test_returns_tuple(self):
        a = jnp.ones((64, 6, 6), jnp.float32)
        b = jnp.ones((64, 6, 6), jnp.float32)
        out = model.panel_multiply(a, b, jnp.full((1, 1), -1.0, jnp.float32))
        assert isinstance(out, tuple) and len(out) == 1
        assert out[0].shape == (64, 6, 6)

    @pytest.mark.parametrize("name,n,bm,bk,bn", model.VARIANTS)
    def test_variant_shapes_lower(self, name, n, bm, bk, bn):
        a = jax.ShapeDtypeStruct((n, bm, bk), jnp.float32)
        b = jax.ShapeDtypeStruct((n, bk, bn), jnp.float32)
        eps = jax.ShapeDtypeStruct((1, 1), jnp.float32)
        lowered = jax.jit(model.panel_multiply).lower(a, b, eps)
        assert lowered is not None

    def test_variant_capacity_is_tile_multiple(self):
        from compile.kernels.batched_gemm import DEFAULT_TILE

        for _, n, *_ in model.VARIANTS:
            assert n % DEFAULT_TILE == 0


class TestSignStep:
    def test_matches_ref(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.standard_normal((32, 32)) * 0.1, jnp.float32)
        x = 0.5 * (x + x.T)
        (got,) = model.sign_step(x)
        np.testing.assert_allclose(got, sign_step_ref(x), rtol=1e-4, atol=1e-5)

    def test_converges_to_sign(self):
        # Newton-Schulz converges when ||I - X^2|| < 1; scale by a bound on
        # the spectral radius.
        rng = np.random.default_rng(6)
        m = rng.standard_normal((24, 24))
        m = 0.5 * (m + m.T) + np.eye(24) * 0.1
        x = jnp.asarray(m / (np.linalg.norm(m, 2) * 1.1), jnp.float32)
        for _ in range(40):
            (x,) = model.sign_step(x)
        evals = np.linalg.eigvalsh(np.asarray(x, np.float64))
        np.testing.assert_allclose(np.abs(evals), 1.0, atol=1e-3)

    def test_sign_idempotent_on_identity(self):
        x = jnp.eye(16, dtype=jnp.float32)
        (got,) = model.sign_step(x)
        np.testing.assert_allclose(got, x, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
    def test_property_step_matches_ref(self, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((n, n)) * 0.2, jnp.float32)
        (got,) = model.sign_step(x)
        np.testing.assert_allclose(got, sign_step_ref(x), rtol=1e-4, atol=1e-4)
