//! The matrix sign iteration (paper Eq. 3):
//! `X_{n+1} = ½ X_n (3I − X_n²)`, all in distributed block-sparse
//! arithmetic with filtering — the workload that makes linear-scaling
//! DFT a stream of SpGEMMs (>80% of runtime, §1).

use crate::blocks::matrix::BlockCsrMatrix;
use crate::dist::distribution::Distribution2d;
use crate::engines::multiply::{multiply_distributed, MultiplyConfig, MultiplyError};
use crate::local::batch::LocalMultStats;

/// Per-iteration trace entry.
#[derive(Clone, Debug)]
pub struct SignIterStats {
    pub iter: usize,
    /// ‖X_{n+1} − X_n‖_F (convergence monitor).
    pub delta: f64,
    /// Occupancy of X after the iteration (fill-in evolution).
    pub occupancy: f64,
    /// Products executed / filtered in the two multiplications.
    pub mult_stats: LocalMultStats,
}

/// Result of a sign-iteration run.
pub struct SignResult {
    pub sign: BlockCsrMatrix,
    pub iters: Vec<SignIterStats>,
    pub converged: bool,
}

/// Run the Newton–Schulz sign iteration on `x0` (must be pre-scaled so
/// `‖X₀‖₂ ≤ 1`, e.g. via [`scale_to_unit_norm`]).  Each iteration costs
/// two distributed multiplications (paper §1).
pub fn sign_iteration(
    x0: &BlockCsrMatrix,
    dist: &Distribution2d,
    cfg: &MultiplyConfig,
    tol: f64,
    max_iter: usize,
) -> Result<SignResult, MultiplyError> {
    let mut x = x0.clone();
    let mut iters = Vec::new();
    let mut converged = false;
    let eye = BlockCsrMatrix::identity(x.row_layout());
    for it in 0..max_iter {
        // X2 = X·X
        let r1 = multiply_distributed(&x, &x, None, dist, cfg)?;
        // Y = 3I - X2
        let mut y = eye.clone();
        y.scale(3.0);
        let y = y.add_scaled(-1.0, &r1.c);
        // X' = 0.5 * X · Y
        let r2 = multiply_distributed(&x, &y, None, dist, cfg)?;
        let mut xn = r2.c;
        xn.scale(0.5);

        let delta = xn.add_scaled(-1.0, &x).frob_norm();
        let mut ms = r1.mult_stats;
        ms.merge(&r2.mult_stats);
        iters.push(SignIterStats {
            iter: it,
            delta,
            occupancy: xn.occupancy(),
            mult_stats: ms,
        });
        x = xn;
        if delta < tol {
            converged = true;
            break;
        }
    }
    Ok(SignResult {
        sign: x,
        iters,
        converged,
    })
}

/// Scale a matrix so the Newton–Schulz iteration converges:
/// `X₀ = A / ‖A‖₂⁺` with the cheap `√(‖A‖₁‖A‖∞)` upper bound.
pub fn scale_to_unit_norm(a: &BlockCsrMatrix) -> (BlockCsrMatrix, f64) {
    let bound = a.to_dense().norm2_upper_bound() * 1.05;
    let mut x = a.clone();
    x.scale(1.0 / bound);
    (x, bound)
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::blocks::filter::FilterConfig;
    use crate::blocks::layout::BlockLayout;
    use crate::dist::grid::ProcGrid;
    use crate::engines::multiply::Engine;
    use crate::workloads::generator::{banded, symmetrize};

    fn gapped_matrix(nblocks: usize, bs: usize, seed: u64) -> BlockCsrMatrix {
        let layout = BlockLayout::uniform(nblocks, bs);
        let m = symmetrize(&banded(&layout, 1, 1.0, seed));
        // push diagonal away from zero for a clean sign
        let mut d = m.to_dense();
        for i in 0..layout.dim() {
            let s = if i % 2 == 0 { 3.0 } else { -3.0 };
            d.add_at(i, i, s);
        }
        BlockCsrMatrix::from_dense(&d, &layout, &layout)
    }

    fn run(engine: Engine, filter: FilterConfig) -> SignResult {
        let a = gapped_matrix(8, 3, 7);
        let (x0, _) = scale_to_unit_norm(&a);
        let grid = ProcGrid::new(2, 2).unwrap();
        let dist =
            Distribution2d::rand_permuted(a.row_layout(), a.col_layout(), &grid, 9);
        let cfg = MultiplyConfig {
            engine,
            filter,
            ..Default::default()
        };
        sign_iteration(&x0, &dist, &cfg, 1e-8, 60).unwrap()
    }

    #[test]
    fn converges_to_involution() {
        let res = run(Engine::PointToPoint, FilterConfig::none());
        assert!(res.converged, "did not converge");
        // sign(A)^2 = I
        let s = res.sign.to_dense();
        let s2 = s.matmul(&s);
        let eye = crate::blocks::dense::DenseMatrix::eye(s.rows);
        assert!(s2.max_abs_diff(&eye) < 1e-5, "{}", s2.max_abs_diff(&eye));
    }

    #[test]
    fn engines_agree_on_sign() {
        let a = run(Engine::PointToPoint, FilterConfig::none());
        let b = run(Engine::OneSided { l: 1 }, FilterConfig::none());
        assert!(a.sign.to_dense().max_abs_diff(&b.sign.to_dense()) < 1e-8);
    }

    #[test]
    fn filtering_preserves_convergence() {
        let res = run(Engine::OneSided { l: 1 }, FilterConfig::uniform(1e-7));
        assert!(res.converged);
        let s = res.sign.to_dense();
        let s2 = s.matmul(&s);
        let eye = crate::blocks::dense::DenseMatrix::eye(s.rows);
        assert!(s2.max_abs_diff(&eye) < 1e-4);
    }

    #[test]
    fn delta_decreases() {
        let res = run(Engine::PointToPoint, FilterConfig::none());
        let deltas: Vec<f64> = res.iters.iter().map(|s| s.delta).collect();
        // quadratic convergence in the tail: last delta much smaller
        assert!(deltas.last().unwrap() < &deltas[0]);
    }
}
